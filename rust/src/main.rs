//! `veloc` — CLI entry point for the VeloC runtime.
//!
//! Subcommands:
//!   info       print platform, artifact and pipeline information
//!   run        run the HACC-like iterative workload under checkpointing
//!   daemon     host the runtime as an out-of-process active backend
//!              serving clients over a Unix domain socket
//!   interval   Young/Daly vs DES interval recommendations
//!   sim        deterministic crash–recover–verify scenarios (one spec,
//!              a saved-trace replay, or the standard sweep matrix)
//!   soak       budgeted randomized chaos runner: the full injection
//!              catalog first, then shuffled re-seeded rounds until the
//!              wall-clock budget is spent; failures print one-line repros
//!   trace      run a traced multi-rank checkpoint wave and export the
//!              span timeline as Chrome trace-event JSON
//!   report     same run, summarized: per-stage latency percentiles
//!   scrape     fetch and validate a daemon's /metrics exposition
//!   postmortem reconstruct a cross-process timeline from a crash's
//!              flight-recorder dumps (`--verify` checks well-formedness)
//!   analyze    attribute each wave's wall-clock to its critical path and
//!              stragglers (from a flight dump or a fresh traced run)
//!
//! Examples live in `examples/` (quickstart, hacc_sim, dnn_training,
//! interval_tuning); this binary is the thin operational front-end.

use anyhow::{anyhow, ensure, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::app::IterativeApp;
use veloc::cluster::FailureScope;
use veloc::interval::{self, Scenario};
use veloc::util::cli::Cli;
use veloc::util::stats::{format_bytes, format_duration, format_throughput};

fn main() {
    let cli = Cli::new(
        "veloc",
        "VEry Low Overhead Checkpointing — paper reproduction runtime",
    )
    .opt(
        "cmd",
        "info",
        "info | run | daemon | interval | sim | soak | trace | report | scrape \
         | postmortem | analyze",
    )
    .opt("config", "", "JSON config file (empty = defaults)")
    .opt("nodes", "4", "simulated nodes")
    .opt("ranks-per-node", "2", "ranks per node")
    .opt("iters", "50", "run: iterations")
    .opt("ckpt-every", "10", "run: checkpoint interval (iterations)")
    .opt("region-mb", "4", "run: per-rank state size (MiB)")
    .opt("mtbf", "2000", "interval: system MTBF seconds")
    .opt("l1-cost", "5", "interval: blocking checkpoint cost seconds")
    .flag("fail", "run: inject a node failure mid-run and restart")
    .flag("aggregate", "coalesce per-rank flushes into shared containers")
    .opt("agg-group-ranks", "0", "aggregation group size (0 = per node)")
    .opt("agg-flush-mb", "32", "aggregation size-threshold drain (MiB)")
    .opt("agg-target", "pfs", "aggregation drain tier: pfs | burst-buffer")
    .opt(
        "placement",
        "",
        "adaptive tier placement: static | fastest-eligible | capacity-aware",
    )
    .flag("delta", "incremental dedup: move only novel chunks per checkpoint")
    .opt("delta-chunk-kb", "8", "delta: average chunk size (KiB, power of two)")
    .opt("delta-max-chain", "8", "delta: checkpoints between forced fulls")
    .opt(
        "restore-cache-mb",
        "",
        "restore: L1 read-through cache size (MiB, 0 = disable the plane)",
    )
    .opt("restore-prefetch-depth", "0", "restore: chain prefetch window (0 = default)")
    .opt("socket", "", "daemon: unix socket path (default <daemon-dir>/veloc.sock)")
    .opt("daemon-dir", "", "daemon: home directory (journal + staging)")
    .opt("queue-depth", "0", "daemon: per-job admission bound (0 = config default)")
    .opt("json", "", "sim: inline scenario spec (one-line JSON)")
    .opt("file", "", "sim: scenario spec file")
    .opt("replay", "", "sim: re-run a saved trace and require an exact match")
    .flag("matrix", "sim: run the standard scenario sweep")
    .opt("filter", "", "sim: only run matrix rows whose injection point contains this")
    .opt("seed", "1", "sim: base seed for the matrix / default spec")
    .opt("trace-out", "", "sim: write the run's event trace to this file")
    .opt("trace-dir", "", "sim/soak: write failing scenario traces into this dir")
    .opt("budget", "60", "soak: wall-clock budget in seconds")
    .opt("soak-out", "", "soak: write the summary JSON to this file")
    .flag("verbose", "soak: print every scenario, not just failures")
    .flag("trace", "record pipeline spans (run/daemon; export via trace-out)")
    .opt("obs-http", "", "daemon: bind /metrics + health endpoint (host:port)")
    .opt("waves", "2", "trace/report: checkpoint waves to run")
    .opt("out", "veloc-trace.json", "trace: Chrome trace-event output file")
    .opt("addr", "", "scrape: observability endpoint (host:port)")
    .flag("wait-ready", "scrape: poll /readyz until ready before scraping")
    .opt("timeout", "10", "scrape: --wait-ready deadline in seconds")
    .opt(
        "flight-dir",
        "",
        "crash-durable flight recorder directory (run/daemon/sim/soak; \
         also: postmortem/analyze input)",
    )
    .flag("verify", "postmortem: check dump well-formedness, exit nonzero on failure")
    .parse();

    let cmd = cli.positional().first().cloned().unwrap_or(cli.get("cmd"));
    let result = match cmd.as_str() {
        "info" => cmd_info(&cli),
        "run" => cmd_run(&cli),
        "daemon" => cmd_daemon(&cli),
        "interval" => cmd_interval(&cli),
        "sim" => cmd_sim(&cli),
        "soak" => cmd_soak(&cli),
        "trace" => cmd_trace(&cli),
        "report" => cmd_report(&cli),
        "scrape" => cmd_scrape(&cli),
        "postmortem" => cmd_postmortem(&cli),
        "analyze" => cmd_analyze(&cli),
        other => {
            eprintln!(
                "unknown command '{other}' (try info | run | daemon | interval | \
                 sim | soak | trace | report | scrape | postmortem | analyze)"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn config_from(cli: &Cli) -> Result<VelocConfig> {
    let path = cli.get("config");
    let mut cfg = if path.is_empty() {
        VelocConfig::default()
    } else {
        VelocConfig::from_file(std::path::Path::new(&path))?
    };
    if path.is_empty() {
        cfg = cfg.with_nodes(cli.get_usize("nodes"), cli.get_usize("ranks-per-node"));
    }
    if cli.get_bool("aggregate") {
        cfg.aggregation.enabled = true;
        cfg.aggregation.group_ranks = cli.get_usize("agg-group-ranks");
        cfg.aggregation.flush_bytes = (cli.get_u64("agg-flush-mb")) << 20;
        cfg.aggregation.target = veloc::aggregation::AggTarget::parse(&cli.get("agg-target"))?;
        if cfg.aggregation.target == veloc::aggregation::AggTarget::BurstBuffer {
            cfg.fabric.with_burst_buffer = true;
        }
    }
    let placement = cli.get("placement");
    if !placement.is_empty() {
        cfg.placement.enabled = true;
        cfg.placement.policy = veloc::storage::PlacementPolicy::parse(&placement)?;
        // A one-tier pool routes trivially; provision the burst buffer so
        // adaptive policies and failover have somewhere to go.
        cfg.fabric.with_burst_buffer = true;
    }
    if cli.get_bool("delta") {
        cfg.delta.enabled = true;
        let avg = cli.get_usize("delta-chunk-kb").max(1) << 10;
        cfg.delta.avg_chunk = avg;
        cfg.delta.min_chunk = (avg / 4).max(64);
        cfg.delta.max_chunk = avg * 8;
        cfg.delta.max_chain = cli.get_u64("delta-max-chain").max(1);
    }
    let cache_mb = cli.get("restore-cache-mb");
    if !cache_mb.is_empty() {
        let mb = cli.get_u64("restore-cache-mb");
        if mb == 0 {
            cfg.restore.enabled = false;
        } else {
            cfg.restore.enabled = true;
            cfg.restore.l1_bytes = mb << 20;
            cfg.restore.l2_bytes = (mb << 20) * 2;
            cfg.restore.max_entry_bytes = cfg.restore.max_entry_bytes.min(mb << 20);
        }
    }
    let depth = cli.get_usize("restore-prefetch-depth");
    if depth > 0 {
        cfg.restore.prefetch_depth = depth;
    }
    if cli.get_bool("trace") {
        cfg.obs.trace = true;
    }
    let obs_http = cli.get("obs-http");
    if !obs_http.is_empty() {
        cfg.obs.http = Some(obs_http);
    }
    let flight_dir = cli.get("flight-dir");
    if !flight_dir.is_empty() {
        cfg.obs.flight_dir = Some(std::path::PathBuf::from(&flight_dir));
    }
    Ok(cfg)
}

fn cmd_info(cli: &Cli) -> Result<()> {
    let cfg = config_from(cli)?;
    let rt = VelocRuntime::new(cfg)?;
    let topo = rt.topology();
    println!(
        "veloc runtime: {} nodes x {} ranks = {} ranks",
        topo.nodes,
        topo.ranks_per_node,
        topo.world_size()
    );
    println!("local tiers per node:");
    for t in rt.env().fabric.local_tiers(0) {
        let s = t.spec();
        println!(
            "  {:<14} write {:>12}  capacity {}",
            s.kind.name(),
            format_throughput(s.write_bw as u64, std::time::Duration::from_secs(1)),
            format_bytes(s.capacity)
        );
    }
    println!("shared tiers:");
    for t in rt.env().fabric.shared_tiers() {
        let s = t.spec();
        println!(
            "  {:<14} write {:>12} (aggregate)  capacity {}",
            s.id,
            format_throughput(s.write_bw as u64, std::time::Duration::from_secs(1)),
            format_bytes(s.capacity)
        );
    }
    if let Some(p) = rt.placement() {
        println!(
            "placement: policy {} (alpha {}, breaker {} errors / probe {})",
            p.config().policy.name(),
            p.config().ewma_alpha,
            p.config().breaker_threshold,
            p.config().breaker_probe_after
        );
        for h in p.health_all() {
            println!(
                "  {:<14} mult {:.2}  breaker {}  routed {} puts / {}",
                h.id,
                h.multiplier,
                if h.breaker_open { "open" } else { "closed" },
                h.routed_puts,
                format_bytes(h.routed_bytes)
            );
        }
    }
    println!();
    print!("{}", rt.engine(0).describe());
    match &rt.env().pjrt {
        Some(e) => println!(
            "pjrt: {} ({} modules)",
            e.platform(),
            e.manifest().modules.len()
        ),
        None => println!("pjrt: disabled (native backends)"),
    }
    Ok(())
}

fn cmd_run(cli: &Cli) -> Result<()> {
    let cfg = config_from(cli)?;
    let rt = VelocRuntime::new(cfg)?;
    let topo = rt.topology();
    let iters = cli.get_u64("iters");
    let every = cli.get_u64("ckpt-every").max(1);
    let mb = cli.get_usize("region-mb");
    let inject = cli.get_bool("fail");

    let world = topo.world_size();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let rt = rt.clone();
            std::thread::spawn(move || -> Result<(u64, u64)> {
                let client = rt.client(rank);
                let mut app =
                    IterativeApp::new(&client, "hacc", 4, mb << 18, 1.0, 42);
                let mut ckpts = 0u64;
                while app.iteration < iters {
                    if rt.kill_switch().is_killed(rank) {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                        continue;
                    }
                    app.step();
                    client.report_utilization(0.9);
                    if app.iteration % every == 0 {
                        let v = app.checkpoint(&client)?;
                        // Strict: a timed-out or failed pipeline aborts the
                        // run instead of counting as a checkpoint.
                        client.checkpoint_wait_done("hacc", v)?;
                        ckpts += 1;
                    }
                }
                Ok((app.iteration, ckpts))
            })
        })
        .collect();

    if inject {
        std::thread::sleep(std::time::Duration::from_millis(300));
        println!("!! injecting failure: node 1 down");
        rt.inject_failure(&FailureScope::Node(1));
        std::thread::sleep(std::time::Duration::from_millis(100));
        // Respawn: revive ranks; a fresh app instance restores its state.
        rt.revive_all();
        for rank in topo.ranks_of_node(1) {
            let client = rt.client(rank);
            let mut app = IterativeApp::new(&client, "hacc", 4, mb << 18, 1.0, 42);
            if let Some(v) = app.restart(&client)? {
                println!("   rank {rank} restarted from v{v}");
            }
        }
    }

    let t0 = Instant::now();
    let mut total_ckpts = 0;
    for (rank, h) in handles.into_iter().enumerate() {
        let (it, ck) = h.join().expect("rank thread")?;
        total_ckpts += ck;
        if rank == 0 {
            println!("rank 0 finished {it} iterations, {ck} checkpoints");
        }
    }
    rt.drain();
    println!(
        "done: {} ranks, {} checkpoints total, wall {}",
        world,
        total_ckpts,
        format_duration(t0.elapsed())
    );
    if let Some(agg) = rt.aggregator() {
        let r = agg.report();
        println!(
            "aggregation: {} containers, {:.1} segments/container, mean write {}, \
             write amplification {:.4}",
            r.containers,
            r.segments_per_container(),
            format_bytes(r.mean_write_bytes() as u64),
            r.write_amplification()
        );
    }
    if let Some(p) = rt.placement() {
        let routed: Vec<String> = p
            .health_all()
            .iter()
            .map(|h| format!("{} {}", h.id, format_bytes(h.routed_bytes)))
            .collect();
        println!(
            "placement ({}): {} failovers, {} breaker trips, routed: {}",
            p.config().policy.name(),
            p.failover_count(),
            p.breaker_trip_count(),
            routed.join(", ")
        );
    }
    let m = rt.metrics();
    let logical = m.counter("delta.bytes.logical");
    if logical > 0 {
        let physical = m.counter("delta.bytes.physical").max(1);
        println!(
            "delta: {} logical -> {} physical ({:.1}x dedup), {} full + {} \
             incremental checkpoints, {} novel of {} chunks",
            format_bytes(logical),
            format_bytes(physical),
            logical as f64 / physical as f64,
            m.counter("delta.ckpt.full"),
            m.counter("delta.ckpt.incremental"),
            m.counter("delta.chunks.novel"),
            m.counter("delta.chunks.total"),
        );
    }
    println!("{}", rt.metrics().to_json().to_pretty());
    Ok(())
}

/// Host the runtime as the out-of-process active backend: bind the Unix
/// socket, replay the journal, serve register/submit/wait/restart until a
/// client sends `shutdown`.
fn cmd_daemon(cli: &Cli) -> Result<()> {
    #[cfg(unix)]
    {
        use veloc::backend::BackendDaemon;
        let mut cfg = config_from(cli)?;
        let dir = cli.get("daemon-dir");
        if !dir.is_empty() {
            cfg.backend.dir = std::path::PathBuf::from(dir);
        }
        let socket = cli.get("socket");
        if !socket.is_empty() {
            cfg.backend.socket = Some(std::path::PathBuf::from(socket));
        }
        let depth = cli.get_usize("queue-depth");
        if depth > 0 {
            cfg.backend.queue_depth = depth;
        }
        let daemon = BackendDaemon::start(cfg)?;
        let replayed = daemon
            .runtime()
            .metrics()
            .counter("backend.journal.replayed");
        if replayed > 0 {
            println!("journal replay: {replayed} acked checkpoint(s) resumed");
        }
        if let Some(addr) = daemon.obs_addr() {
            println!(
                "veloc daemon: observability on http://{addr}/metrics (+ /healthz, /readyz)"
            );
        }
        println!(
            "veloc daemon: serving on {} (dir {}, queue depth {})",
            daemon.backend_config().socket_path().display(),
            daemon.backend_config().dir.display(),
            daemon.backend_config().queue_depth
        );
        daemon.serve()?;
        println!("veloc daemon: shut down cleanly");
        Ok(())
    }
    #[cfg(not(unix))]
    {
        let _ = cli;
        anyhow::bail!("veloc daemon requires Unix domain sockets (unix only)");
    }
}

fn cmd_sim(cli: &Cli) -> Result<()> {
    use veloc::obs::TraceRecorder;
    use veloc::sim::{
        base_spec, replay_file, run_scenario_with_obs, standard_matrix, ScenarioSpec,
    };

    let replay = cli.get("replay");
    if !replay.is_empty() {
        let report = replay_file(std::path::Path::new(&replay))?;
        println!("replay ok: {}", report.summary());
        return Ok(());
    }
    let trace_dir = cli.get("trace-dir");
    if !trace_dir.is_empty() {
        std::fs::create_dir_all(&trace_dir)?;
    }
    let flight_dir = cli.get("flight-dir");
    if !flight_dir.is_empty() {
        std::fs::create_dir_all(&flight_dir)?;
    }

    if cli.get_bool("matrix") {
        let seed = cli.get_u64("seed");
        let mut specs = standard_matrix(seed);
        let filter = cli.get("filter");
        if !filter.is_empty() {
            specs.retain(|s| s.inject.name().contains(&filter));
            if specs.is_empty() {
                anyhow::bail!("--filter {filter:?} matches no matrix row");
            }
        }
        println!("sim matrix: {} scenarios (base seed {seed})", specs.len());
        let mut failed = 0usize;
        for (i, spec) in specs.iter().enumerate() {
            // Span recording rides along so a failure ships a timeline
            // artifact; span timestamps never enter the event trace, so
            // replay comparison stays exact. With --flight-dir each row
            // gets its own crash-durable dump directory.
            let tracer = TraceRecorder::new(true);
            let row_flight = (!flight_dir.is_empty()).then(|| {
                std::path::Path::new(&flight_dir)
                    .join(format!("scenario-{i:02}-seed{}", spec.seed))
            });
            let (result, trace) =
                run_scenario_with_obs(spec, Some(Arc::clone(&tracer)), row_flight.as_deref());
            match result {
                Ok(report) => println!("  ok   [{i:>2}] {}", report.summary()),
                Err(e) => {
                    failed += 1;
                    eprintln!("  FAIL [{i:>2}] {e:#}");
                    if let Some(fd) = &row_flight {
                        eprintln!("         flight: {}", fd.display());
                    }
                    if !trace_dir.is_empty() {
                        let path = std::path::Path::new(&trace_dir)
                            .join(format!("scenario-{i:02}-seed{}.json", spec.seed));
                        if trace.save(spec, &path).is_ok() {
                            eprintln!("         trace: {}", path.display());
                        }
                        let spans = std::path::Path::new(&trace_dir)
                            .join(format!("scenario-{i:02}-seed{}.spans.json", spec.seed));
                        tracer.close_open_waves();
                        let doc = tracer.to_chrome_json().to_pretty();
                        if std::fs::write(&spans, doc).is_ok() {
                            eprintln!("         spans: {}", spans.display());
                        }
                    }
                }
            }
        }
        if failed > 0 {
            anyhow::bail!("{failed} scenario(s) failed — every FAIL line above carries its one-line repro");
        }
        println!("all scenarios passed");
        return Ok(());
    }

    // Single scenario: --json, --file, or the seeded default spec.
    let inline = cli.get("json");
    let file = cli.get("file");
    let spec = if !inline.is_empty() {
        ScenarioSpec::from_str_json(&inline)?
    } else if !file.is_empty() {
        ScenarioSpec::from_str_json(&std::fs::read_to_string(&file)?)?
    } else {
        base_spec(cli.get_u64("seed"))
    };
    let tracer = TraceRecorder::new(true);
    let single_flight =
        (!flight_dir.is_empty()).then(|| std::path::PathBuf::from(&flight_dir));
    let (result, trace) =
        run_scenario_with_obs(&spec, Some(Arc::clone(&tracer)), single_flight.as_deref());
    let trace_out = cli.get("trace-out");
    if !trace_out.is_empty() {
        trace.save(&spec, std::path::Path::new(&trace_out))?;
        println!("trace written to {trace_out}");
    }
    match result {
        Ok(report) => {
            println!("ok: {}", report.summary());
            Ok(())
        }
        Err(e) => {
            if let Some(fd) = &single_flight {
                eprintln!("failing flight dump: {}", fd.display());
            }
            if !trace_dir.is_empty() {
                let path = std::path::Path::new(&trace_dir)
                    .join(format!("scenario-seed{}.json", spec.seed));
                if trace.save(&spec, &path).is_ok() {
                    eprintln!("failing trace: {}", path.display());
                }
                let spans = std::path::Path::new(&trace_dir)
                    .join(format!("scenario-seed{}.spans.json", spec.seed));
                tracer.close_open_waves();
                if std::fs::write(&spans, tracer.to_chrome_json().to_pretty()).is_ok() {
                    eprintln!("failing spans: {}", spans.display());
                }
            }
            Err(e)
        }
    }
}

/// Budgeted randomized chaos soak: round 0 runs the entire injection
/// catalog at the base seed (full coverage regardless of budget), then
/// re-seeded shuffled rounds until `--budget` seconds elapse. Every
/// failure prints the one-line `veloc sim --json '…'` repro and, with
/// `--trace-dir`, saves its event trace; `--soak-out` writes the summary
/// JSON CI uploads as an artifact.
fn cmd_soak(cli: &Cli) -> Result<()> {
    use veloc::sim::{run_soak, SoakConfig};

    let budget = Duration::from_secs(cli.get_u64("budget"));
    let filter = cli.get("filter");
    let trace_dir = cli.get("trace-dir");
    let flight_dir = cli.get("flight-dir");
    let cfg = SoakConfig {
        budget,
        base_seed: cli.get_u64("seed"),
        trace_dir: (!trace_dir.is_empty()).then(|| std::path::PathBuf::from(&trace_dir)),
        flight_dir: (!flight_dir.is_empty()).then(|| std::path::PathBuf::from(&flight_dir)),
        filter: (!filter.is_empty()).then(|| filter.clone()),
        verbose: cli.get_bool("verbose"),
    };
    println!(
        "soak: budget {}, base seed {} (round 0 = full catalog)",
        format_duration(budget),
        cfg.base_seed
    );
    let outcome = run_soak(&cfg);
    println!(
        "soak done: {} runs over {} round(s) in {}, {} failure(s)",
        outcome.runs,
        outcome.rounds,
        format_duration(outcome.elapsed),
        outcome.failures.len()
    );
    for (fam, n) in &outcome.coverage {
        println!("  {fam:<24} {n:>6} runs");
    }
    let out = cli.get("soak-out");
    if !out.is_empty() {
        std::fs::write(&out, outcome.to_json().to_pretty())?;
        println!("summary written to {out}");
    }
    ensure!(
        outcome.runs > 0,
        "soak executed no scenarios (filter {filter:?} matches nothing?)"
    );
    if !outcome.failures.is_empty() {
        anyhow::bail!(
            "{} soak failure(s) — every FAIL line above carries its one-line repro",
            outcome.failures.len()
        );
    }
    Ok(())
}

/// Run `--waves` checkpoint waves across every rank with span recording
/// forced on, drain, and hand back the runtime (whose recorder now holds
/// the full timeline). Shared by `veloc trace` and `veloc report`.
fn run_traced_waves(cli: &Cli) -> Result<Arc<VelocRuntime>> {
    let mut cfg = config_from(cli)?;
    cfg.obs.trace = true;
    let rt = VelocRuntime::new(cfg)?;
    let world = rt.topology().world_size();
    let waves = cli.get_u64("waves").max(1);
    let bytes = (cli.get_usize("region-mb").max(1)) << 18;
    let clients: Vec<_> = (0..world).map(|r| rt.client(r)).collect();
    for c in &clients {
        c.mem_protect(0, vec![(c.rank() + 1) as u8; bytes]);
    }
    for v in 1..=waves {
        for c in &clients {
            c.checkpoint("app", v)?;
        }
        for c in &clients {
            c.checkpoint_wait_done("app", v)?;
        }
    }
    rt.drain();
    rt.tracer()
        .validate()
        .map_err(|e| anyhow!("span timeline malformed: {e}"))?;
    Ok(rt)
}

/// Record a multi-rank wave and export its span timeline as Chrome
/// trace-event JSON (load the file in `chrome://tracing` or Perfetto).
fn cmd_trace(cli: &Cli) -> Result<()> {
    let rt = run_traced_waves(cli)?;
    let tracer = rt.tracer();
    let spans = tracer.snapshot();
    let out = cli.get("out");
    std::fs::write(&out, tracer.to_chrome_json().to_pretty())?;
    println!(
        "trace: {} spans over {} wave(s), {} dropped at capacity",
        spans.len(),
        cli.get_u64("waves").max(1),
        tracer.dropped()
    );
    println!("written to {out}");
    Ok(())
}

/// Record a multi-rank wave and print per-stage latency percentiles,
/// grouped by pipeline stage and resilience level.
fn cmd_report(cli: &Cli) -> Result<()> {
    use veloc::obs::stage_summary;
    let rt = run_traced_waves(cli)?;
    let spans = rt.tracer().snapshot();
    let rows = stage_summary(&spans);
    ensure!(!rows.is_empty(), "no closed spans recorded");
    println!(
        "{:<24} {:<10} {:>6} {:>12} {:>12} {:>12}",
        "stage", "level", "count", "p50", "p95", "p99"
    );
    for (stage, level, samples) in &rows {
        println!(
            "{:<24} {:<10} {:>6} {:>12} {:>12} {:>12}",
            stage,
            level,
            samples.observed(),
            format_duration(Duration::from_secs_f64(samples.p50())),
            format_duration(Duration::from_secs_f64(samples.p95())),
            format_duration(Duration::from_secs_f64(samples.p99())),
        );
    }
    Ok(())
}

/// Fetch a daemon's `/metrics` exposition, parse and validate it, and
/// print the families it serves.
fn cmd_scrape(cli: &Cli) -> Result<()> {
    use veloc::obs::prom::parse_exposition;
    use veloc::obs::{http_get, wait_ready};
    let addr = cli.get("addr");
    ensure!(!addr.is_empty(), "--addr host:port required (see daemon --obs-http)");
    if cli.get_bool("wait-ready") {
        // A daemon that never comes up must fail the scrape (nonzero
        // exit), not hang CI: the deadline is explicit and configurable.
        let timeout = Duration::from_secs(cli.get_u64("timeout").max(1));
        wait_ready(&addr, timeout).map_err(|e| {
            anyhow!("daemon not ready within {}s: {e:#}", timeout.as_secs())
        })?;
    }
    let (code, body) = http_get(&addr, "/metrics", Duration::from_secs(5))?;
    ensure!(code == 200, "GET /metrics returned {code}");
    let families =
        parse_exposition(&body).map_err(|e| anyhow!("invalid exposition: {e}"))?;
    println!("scrape ok: {} metric families from {addr}", families.len());
    for f in &families {
        println!("  {:<40} {} ({} samples)", f.name, f.typ, f.samples.len());
    }
    Ok(())
}

/// Reconstruct the cross-process timeline from a flight-dump directory:
/// one `.vfr` stream (plus its rotated `.old` generation) per process,
/// merged by timestamp. `--verify` additionally checks well-formedness —
/// meta-led streams, per-segment timestamp monotonicity, span parent
/// closure — and exits nonzero on any violation. Either way the command
/// lists the acked-but-unsettled submissions the crash stranded.
fn cmd_postmortem(cli: &Cli) -> Result<()> {
    use veloc::obs::flight;
    use veloc::obs::FlightKind;

    let dir = cli
        .positional()
        .get(1)
        .cloned()
        .unwrap_or_else(|| cli.get("flight-dir"));
    ensure!(
        !dir.is_empty(),
        "usage: veloc postmortem <dump-dir> [--verify] (or --flight-dir <dir>)"
    );
    let dir = std::path::PathBuf::from(&dir);
    let scans = flight::read_dir(&dir)?;
    ensure!(
        !scans.is_empty(),
        "no .vfr flight streams under {}",
        dir.display()
    );
    for (path, scan) in &scans {
        let torn = match &scan.truncated {
            Some(t) => format!("  [torn tail: {t}]"),
            None => String::new(),
        };
        println!(
            "stream {}: {} record(s), {} bytes{torn}",
            path.display(),
            scan.entries.len(),
            scan.bytes_scanned
        );
    }

    if cli.get_bool("verify") {
        let report = flight::verify(&scans).map_err(|e| anyhow!("verify FAILED: {e}"))?;
        println!(
            "verify ok: {} stream(s), {} record(s) ({} spans, {} events, {} snapshots), \
             processes [{}], {} torn tail(s), {} unsettled",
            report.files,
            report.entries,
            report.spans,
            report.events,
            report.snapshots,
            report.processes.join(", "),
            report.torn,
            report.unsettled.len()
        );
    }

    let merged = flight::merge(&scans);
    println!("-- timeline ({} record(s)) --", merged.len());
    for e in &merged {
        let desc = match e.kind {
            FlightKind::Span => {
                let name = e.body.str_or("name", "?");
                match e.body.get("end_us").and_then(veloc::util::json::Json::as_u64) {
                    Some(end) => {
                        let start =
                            e.body.get("start_us").and_then(veloc::util::json::Json::as_u64);
                        format!(
                            "{name} ({} us)",
                            end.saturating_sub(start.unwrap_or(end))
                        )
                    }
                    None => format!("{name} (open)"),
                }
            }
            _ => e.body.to_string(),
        };
        println!(
            "{:>16} {:<8} {:<7} {desc}",
            e.t_us,
            e.process,
            e.kind.name()
        );
    }

    let stranded = flight::unsettled(&merged);
    if stranded.is_empty() {
        println!("no acked-but-unsettled submissions");
    } else {
        println!("-- acked but never settled ({}) --", stranded.len());
        for u in &stranded {
            println!("  {}", u.to_string());
        }
    }
    Ok(())
}

/// Wave critical-path attribution: reconstruct spans either from a flight
/// dump (`--flight-dir`) or from a fresh traced multi-rank run (the
/// `trace`/`report` wave driver), then attribute each wave's wall-clock
/// to the stages on its critical path and flag stragglers.
fn cmd_analyze(cli: &Cli) -> Result<()> {
    use veloc::obs::{critpath, flight};

    let dir = cli
        .positional()
        .get(1)
        .cloned()
        .unwrap_or_else(|| cli.get("flight-dir"));
    let spans = if !dir.is_empty() {
        let dir = std::path::PathBuf::from(&dir);
        let scans = flight::read_dir(&dir)?;
        ensure!(
            !scans.is_empty(),
            "no .vfr flight streams under {}",
            dir.display()
        );
        let spans: Vec<_> = flight::merge(&scans)
            .iter()
            .filter_map(flight::entry_to_span)
            .collect();
        ensure!(
            !spans.is_empty(),
            "{}: flight dump holds no span records (was tracing enabled?)",
            dir.display()
        );
        spans
    } else {
        run_traced_waves(cli)?.tracer().snapshot()
    };
    let waves = critpath::analyze(&spans);
    ensure!(!waves.is_empty(), "no complete checkpoint waves to analyze");
    print!("{}", critpath::render(&waves));
    Ok(())
}

fn cmd_interval(cli: &Cli) -> Result<()> {
    let mtbf = cli.get_f64("mtbf");
    let l1 = cli.get_f64("l1-cost");
    let s = Scenario {
        mtbf,
        l1_cost: l1,
        l23_lag: l1 * 2.0,
        l4_lag: l1 * 12.0,
        restart_fast: l1 * 3.0,
        restart_pfs: l1 * 30.0,
        work: mtbf * 20.0,
        mix: Default::default(),
    };
    println!("scenario: MTBF {mtbf} s, L1 cost {l1} s");
    println!("  young        : {:>10.1} s", interval::young(l1, mtbf));
    println!("  daly         : {:>10.1} s", interval::daly(l1, mtbf));
    let (w, e) = interval::optimal_interval(&s, 16, 8, 7);
    println!("  DES optimum  : {:>10.1} s (efficiency {:.3})", w, e);
    Ok(())
}
