//! Chain reassembly: turn a restored container (VCKP, zlib'd VCKP, or a
//! VDLT delta container) back into the exact [`Checkpoint`] it encodes.
//!
//! For delta containers the needed chunks are resolved in cost order:
//! payloads carried by the container itself, then the node's chunk store
//! (fingerprint-verified), then a walk up the manifest chain fetching
//! ancestor containers through the caller-provided `fetch` closure — each
//! resilience level supplies its own fetcher (local tiers, partner tiers,
//! PFS objects, aggregated containers, erasure rebuilds). A broken chain
//! (ancestor container or chunk unavailable) is an error; the engine's
//! restore loop treats it like any other corrupt copy and falls back to
//! the next level — and recovery's version descent falls back to an older
//! version whose chain is intact, bounded by the periodic forced fulls.

use crate::delta::chunker::Fingerprint;
use crate::delta::manifest;
use crate::delta::store::ChunkStore;
use crate::modules::transfer::maybe_decompress;
use crate::util::bytes::Checkpoint;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::HashMap;

/// Hard safety bound on chain walks (configuration bounds real chains far
/// lower via forced fulls).
const MAX_CHAIN_HOPS: usize = 1024;

/// Reassemble a checkpoint from container bytes. `store` is the optional
/// node-local chunk store fast path; `fetch` returns the (possibly
/// compressed) container bytes of an ancestor version at the same level.
/// Non-delta containers pass straight through, so callers can use this
/// unconditionally in place of `Checkpoint::decode`.
pub fn materialize(
    data: Vec<u8>,
    store: Option<&ChunkStore>,
    fetch: &dyn Fn(u64) -> Option<Vec<u8>>,
) -> Result<Checkpoint> {
    let raw = maybe_decompress(data)?;
    if !manifest::is_delta(&raw) {
        return Checkpoint::decode(&raw);
    }
    let (target, mut have) = manifest::decode(&raw)?;
    let needed = target.fp_set();

    let missing = |have: &HashMap<Fingerprint, Vec<u8>>| -> Vec<Fingerprint> {
        needed
            .iter()
            .filter(|fp| !have.contains_key(*fp))
            .copied()
            .collect()
    };

    // Node store fast path (fingerprint-verified, so a stale or wiped
    // store degrades to a miss, never to wrong bytes).
    if let Some(s) = store {
        for fp in missing(&have) {
            if let Some(d) = s.get(&fp) {
                have.insert(fp, d);
            }
        }
    }

    // Walk the manifest chain for whatever is still unresolved.
    let mut base = target.base;
    let mut hops = 0;
    while !missing(&have).is_empty() {
        let Some(v) = base else {
            bail!(
                "delta restore of {} v{} rank {}: {} chunk(s) missing and the \
                 manifest chain is exhausted",
                target.name,
                target.version,
                target.rank,
                missing(&have).len()
            );
        };
        hops += 1;
        if hops > MAX_CHAIN_HOPS {
            bail!(
                "manifest chain of {} v{} exceeds {MAX_CHAIN_HOPS} links",
                target.name,
                target.version
            );
        }
        let bytes = fetch(v).ok_or_else(|| {
            anyhow!(
                "delta restore of {} v{} rank {}: chain broken — version {v} unavailable",
                target.name,
                target.version,
                target.rank
            )
        })?;
        let braw = maybe_decompress(bytes)?;
        if !manifest::is_delta(&braw) {
            bail!("chain version {v} of {} is not a delta container", target.name);
        }
        let (ancestor, carried) = manifest::decode(&braw)?;
        for (fp, d) in carried {
            if needed.contains(&fp) {
                have.entry(fp).or_insert(d);
            }
        }
        base = ancestor.base;
    }

    // Assemble regions in manifest order; lengths double-checked against
    // the recipe (payloads were fingerprint-verified on the way in).
    let mut ckpt = Checkpoint::new(&target.name, target.rank, target.iteration);
    for r in &target.regions {
        let total: usize = r.chunks.iter().map(|c| c.len).sum();
        let mut data = Vec::with_capacity(total);
        for c in &r.chunks {
            let piece = have
                .get(&c.fp)
                .expect("every needed fingerprint resolved above");
            ensure!(
                piece.len() == c.len,
                "chunk {} of region {} is {} bytes, recipe says {}",
                c.fp.hex(),
                r.id,
                piece.len(),
                c.len
            );
            data.extend_from_slice(piece);
        }
        ckpt.push_region(r.id, data);
    }
    Ok(ckpt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{DeltaConfig, DeltaState};
    use crate::storage::{FabricConfig, StorageFabric};
    use std::collections::BTreeMap;

    fn state() -> (StorageFabric, std::sync::Arc<DeltaState>) {
        let f = StorageFabric::build(&FabricConfig {
            nodes: 1,
            ..Default::default()
        })
        .unwrap();
        let cfg = DeltaConfig {
            enabled: true,
            min_chunk: 64,
            avg_chunk: 256,
            max_chunk: 1024,
            max_chain: 4,
        };
        let s = DeltaState::new(cfg, &f, None).unwrap();
        (f, s)
    }

    fn ckpt(version: u64, data: &[u8]) -> Checkpoint {
        let mut c = Checkpoint::new("app", 0, version);
        c.push_region(0, data.to_vec());
        c.push_region(3, data.iter().rev().copied().collect());
        c
    }

    #[test]
    fn vckp_passthrough() {
        let c = ckpt(1, &[5u8; 2000]);
        let out = materialize(c.encode(), None, &|_| None).unwrap();
        assert_eq!(out, c);
    }

    /// Aperiodic filler (a plain `(i * k) as u8` repeats every 256 bytes,
    /// which would dedup chunks *within* one checkpoint and skew tests).
    fn noise(n: usize) -> Vec<u8> {
        (0..n as u32)
            .map(|i| (i.wrapping_mul(2_654_435_761) >> 13) as u8)
            .collect()
    }

    #[test]
    fn chain_materializes_bit_for_bit() {
        let (_f, state) = state();
        let mut data = noise(12_288);
        let mut containers: BTreeMap<u64, Vec<u8>> = BTreeMap::new();
        let mut expected = None;
        for v in 1..=3u64 {
            data[(v as usize) * 700] ^= 0xA5;
            let c = ckpt(v, &data);
            containers.insert(v, state.encode_checkpoint(&c, v, 0, &|_| true).unwrap());
            expected = Some(c);
        }
        let last = expected.unwrap();
        // Through the chain only (no store).
        let fetch = |v: u64| containers.get(&v).cloned();
        let out = materialize(containers[&3].clone(), None, &fetch).unwrap();
        assert_eq!(out, last);
        assert_eq!(out.encode(), last.encode(), "re-encode must be identical");
        // Through the store only (no chain fetch).
        let out = materialize(
            containers[&3].clone(),
            Some(state.store(0).as_ref()),
            &|_| None,
        )
        .unwrap();
        assert_eq!(out, last);
    }

    #[test]
    fn broken_chain_is_an_error_not_wrong_bytes() {
        let (_f, state) = state();
        let mut data = noise(8_192);
        let mut containers: BTreeMap<u64, Vec<u8>> = BTreeMap::new();
        for v in 1..=3u64 {
            containers.insert(
                v,
                state.encode_checkpoint(&ckpt(v, &data), v, 0, &|_| true).unwrap(),
            );
            data[(v as usize) * 900] ^= 0x3C;
        }
        // Lose the middle link and hide the store: v3 must fail loudly.
        let fetch = |v: u64| {
            if v == 2 {
                None
            } else {
                containers.get(&v).cloned()
            }
        };
        let err = materialize(containers[&3].clone(), None, &fetch)
            .unwrap_err()
            .to_string();
        assert!(err.contains("chain broken"), "{err}");
        // The full base still materializes.
        let out = materialize(containers[&1].clone(), None, &|_| None).unwrap();
        assert_eq!(out.meta.iteration, 1);
    }
}
