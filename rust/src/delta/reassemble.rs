//! Chain reassembly: turn a restored container (VCKP, zlib'd VCKP, or a
//! VDLT delta container) back into the exact [`Checkpoint`] it encodes.
//!
//! For delta containers the needed chunks are resolved in cost order:
//! payloads carried by the container itself, then the node's chunk store
//! (fingerprint-verified), then a walk up the manifest chain fetching
//! ancestor containers through the caller-provided `fetch` closure — each
//! resilience level supplies its own fetcher (local tiers, partner tiers,
//! PFS objects, aggregated containers, erasure rebuilds). A broken chain
//! (ancestor container or chunk unavailable) is a typed [`RestoreError`];
//! the engine's restore loop treats it like any other corrupt copy and
//! falls back to the next level — and recovery's version descent falls
//! back to an older version whose chain is intact, bounded by the
//! periodic forced fulls.
//!
//! The walk also records which ancestor versions it actually consulted as
//! a [`ChainPlan`] — the canonical hop list that the restore subsystem's
//! prefetcher and cache share as one identity (see [`crate::restore`]).

use crate::delta::chunker::Fingerprint;
use crate::delta::manifest;
use crate::delta::manifest::DeltaManifest;
use crate::delta::store::ChunkStore;
use crate::modules::transfer::maybe_decompress;
use crate::util::bytes::Checkpoint;
use anyhow::{ensure, Result};
use std::collections::HashMap;

/// Hard safety bound on chain walks (configuration bounds real chains far
/// lower via forced fulls).
const MAX_CHAIN_HOPS: usize = 1024;

/// Typed failure modes of delta-chain reassembly. Callers match on the
/// variant (via `anyhow::Error::downcast_ref`) instead of grepping the
/// rendered message; the [`std::fmt::Display`] text stays close to the
/// historical strings for log continuity.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RestoreError {
    /// An ancestor container the chain depends on could not be fetched.
    ChainBroken {
        /// Checkpoint name of the restore target.
        name: String,
        /// Version being restored.
        version: u64,
        /// Rank being restored.
        rank: usize,
        /// The ancestor version that was unavailable.
        missing: u64,
    },
    /// The chain ended (reached a full container) with chunks still
    /// unresolved — the target references data no ancestor carries.
    ChainExhausted {
        /// Checkpoint name of the restore target.
        name: String,
        /// Version being restored.
        version: u64,
        /// Rank being restored.
        rank: usize,
        /// How many chunks were still missing when the chain ran out.
        missing_chunks: usize,
    },
    /// The walk exceeded the hard hop bound — a cycle or corrupt base
    /// pointers, never a legitimate chain (forced fulls bound real ones).
    ChainTooLong {
        /// Checkpoint name of the restore target.
        name: String,
        /// Version being restored.
        version: u64,
        /// The hop bound that was exceeded.
        limit: usize,
    },
    /// An ancestor fetched mid-chain was not a delta container.
    NotDelta {
        /// Checkpoint name of the restore target.
        name: String,
        /// The chain version that had the wrong container type.
        version: u64,
    },
}

impl std::fmt::Display for RestoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RestoreError::ChainBroken {
                name,
                version,
                rank,
                missing,
            } => write!(
                f,
                "delta restore of {name} v{version} rank {rank}: chain broken — \
                 version {missing} unavailable"
            ),
            RestoreError::ChainExhausted {
                name,
                version,
                rank,
                missing_chunks,
            } => write!(
                f,
                "delta restore of {name} v{version} rank {rank}: {missing_chunks} \
                 chunk(s) missing and the manifest chain is exhausted"
            ),
            RestoreError::ChainTooLong {
                name,
                version,
                limit,
            } => write!(f, "manifest chain of {name} v{version} exceeds {limit} links"),
            RestoreError::NotDelta { name, version } => {
                write!(f, "chain version {version} of {name} is not a delta container")
            }
        }
    }
}

impl std::error::Error for RestoreError {}

/// The resolved identity of one chain walk: which container was the
/// target and which ancestor versions the walk actually consulted, in
/// walk order. Prefetchers and caches key off this one canonical plan
/// instead of re-deriving hop lists per fetch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChainPlan {
    /// Checkpoint name of the restore target.
    pub name: String,
    /// Rank of the restore target.
    pub rank: usize,
    /// Version of the restore target.
    pub version: u64,
    /// Ancestor versions fetched during the walk, nearest first. Empty
    /// for non-delta containers and for deltas fully resolved from
    /// carried payloads or the node chunk store.
    pub hops: Vec<u64>,
}

impl ChainPlan {
    /// A plan with no hops (passthrough / store-resolved restores).
    fn direct(name: &str, rank: usize, version: u64) -> Self {
        ChainPlan {
            name: name.to_string(),
            rank,
            version,
            hops: Vec::new(),
        }
    }
}

/// Predict the ancestor versions a delta container's chain will visit,
/// from manifest metadata alone: the walk starts at `base` and takes
/// `chain_len` hops total, and writers advance versions by a fixed
/// stride, so extrapolating `version - base` backwards from `base`
/// reconstructs the likely hop list without fetching anything. This is
/// speculation for prefetch — a mispredicted hop costs one wasted fetch,
/// never a wrong restore (the authoritative walk follows real `base`
/// pointers).
pub fn predicted_hops(m: &DeltaManifest) -> Vec<u64> {
    let Some(base) = m.base else {
        return Vec::new();
    };
    let stride = m.version.saturating_sub(base).max(1);
    let mut hops = Vec::with_capacity(m.chain_len as usize);
    let mut v = base;
    for _ in 0..m.chain_len.min(MAX_CHAIN_HOPS as u64) {
        hops.push(v);
        if v <= stride {
            break;
        }
        v -= stride;
    }
    hops
}

/// Reassemble a checkpoint from container bytes. `store` is the optional
/// node-local chunk store fast path; `fetch` returns the (possibly
/// compressed) container bytes of an ancestor version at the same level.
/// Non-delta containers pass straight through, so callers can use this
/// unconditionally in place of `Checkpoint::decode`.
pub fn materialize(
    data: Vec<u8>,
    store: Option<&ChunkStore>,
    fetch: &dyn Fn(u64) -> Option<Vec<u8>>,
) -> Result<Checkpoint> {
    materialize_planned(data, store, fetch).map(|(ckpt, _)| ckpt)
}

/// [`materialize`] that also returns the [`ChainPlan`] the walk resolved
/// — the hop list restore-side caching and prefetch key off.
pub fn materialize_planned(
    data: Vec<u8>,
    store: Option<&ChunkStore>,
    fetch: &dyn Fn(u64) -> Option<Vec<u8>>,
) -> Result<(Checkpoint, ChainPlan)> {
    let raw = maybe_decompress(data)?;
    if !manifest::is_delta(&raw) {
        let ckpt = Checkpoint::decode(&raw)?;
        let plan = ChainPlan::direct(&ckpt.meta.name, ckpt.meta.rank, ckpt.meta.iteration);
        return Ok((ckpt, plan));
    }
    let (target, mut have) = manifest::decode(&raw)?;
    let needed = target.fp_set();

    let missing = |have: &HashMap<Fingerprint, Vec<u8>>| -> Vec<Fingerprint> {
        needed
            .iter()
            .filter(|fp| !have.contains_key(*fp))
            .copied()
            .collect()
    };

    // Node store fast path (fingerprint-verified, so a stale or wiped
    // store degrades to a miss, never to wrong bytes).
    if let Some(s) = store {
        for fp in missing(&have) {
            if let Some(d) = s.get(&fp) {
                have.insert(fp, d);
            }
        }
    }

    // Walk the manifest chain for whatever is still unresolved.
    let mut plan = ChainPlan::direct(&target.name, target.rank, target.version);
    let mut base = target.base;
    while !missing(&have).is_empty() {
        let Some(v) = base else {
            return Err(RestoreError::ChainExhausted {
                name: target.name.clone(),
                version: target.version,
                rank: target.rank,
                missing_chunks: missing(&have).len(),
            }
            .into());
        };
        if plan.hops.len() >= MAX_CHAIN_HOPS {
            return Err(RestoreError::ChainTooLong {
                name: target.name.clone(),
                version: target.version,
                limit: MAX_CHAIN_HOPS,
            }
            .into());
        }
        let Some(bytes) = fetch(v) else {
            return Err(RestoreError::ChainBroken {
                name: target.name.clone(),
                version: target.version,
                rank: target.rank,
                missing: v,
            }
            .into());
        };
        plan.hops.push(v);
        let braw = maybe_decompress(bytes)?;
        if !manifest::is_delta(&braw) {
            return Err(RestoreError::NotDelta {
                name: target.name.clone(),
                version: v,
            }
            .into());
        }
        let (ancestor, carried) = manifest::decode(&braw)?;
        for (fp, d) in carried {
            if needed.contains(&fp) {
                have.entry(fp).or_insert(d);
            }
        }
        base = ancestor.base;
    }

    // Assemble regions in manifest order; lengths double-checked against
    // the recipe (payloads were fingerprint-verified on the way in). The
    // recipe lengths themselves are untrusted: sum them checked, and only
    // pre-size the buffer once every piece's real length matched — a
    // hostile manifest must not drive a giant allocation (or an overflow)
    // off declared lengths its payloads can't back.
    let mut ckpt = Checkpoint::new(&target.name, target.rank, target.iteration);
    for r in &target.regions {
        let total = r
            .chunks
            .iter()
            .try_fold(0usize, |acc, c| acc.checked_add(c.len))
            .ok_or_else(|| {
                anyhow::anyhow!("region {} recipe lengths overflow", r.id)
            })?;
        for c in &r.chunks {
            let piece = have
                .get(&c.fp)
                .expect("every needed fingerprint resolved above");
            ensure!(
                piece.len() == c.len,
                "chunk {} of region {} is {} bytes, recipe says {}",
                c.fp.hex(),
                r.id,
                piece.len(),
                c.len
            );
        }
        let mut data = Vec::with_capacity(total);
        for c in &r.chunks {
            data.extend_from_slice(&have[&c.fp]);
        }
        ckpt.push_region(r.id, data);
    }
    Ok((ckpt, plan))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{DeltaConfig, DeltaState};
    use crate::storage::{FabricConfig, StorageFabric};
    use std::collections::BTreeMap;

    fn state() -> (StorageFabric, std::sync::Arc<DeltaState>) {
        let f = StorageFabric::build(&FabricConfig {
            nodes: 1,
            ..Default::default()
        })
        .unwrap();
        let cfg = DeltaConfig {
            enabled: true,
            min_chunk: 64,
            avg_chunk: 256,
            max_chunk: 1024,
            max_chain: 4,
        };
        let s = DeltaState::new(cfg, &f, None).unwrap();
        (f, s)
    }

    fn ckpt(version: u64, data: &[u8]) -> Checkpoint {
        let mut c = Checkpoint::new("app", 0, version);
        c.push_region(0, data.to_vec());
        c.push_region(3, data.iter().rev().copied().collect());
        c
    }

    #[test]
    fn vckp_passthrough() {
        let c = ckpt(1, &[5u8; 2000]);
        let out = materialize(c.encode(), None, &|_| None).unwrap();
        assert_eq!(out, c);
    }

    /// Aperiodic filler (a plain `(i * k) as u8` repeats every 256 bytes,
    /// which would dedup chunks *within* one checkpoint and skew tests).
    fn noise(n: usize) -> Vec<u8> {
        (0..n as u32)
            .map(|i| (i.wrapping_mul(2_654_435_761) >> 13) as u8)
            .collect()
    }

    #[test]
    fn chain_materializes_bit_for_bit() {
        let (_f, state) = state();
        let mut data = noise(12_288);
        let mut containers: BTreeMap<u64, Vec<u8>> = BTreeMap::new();
        let mut expected = None;
        for v in 1..=3u64 {
            data[(v as usize) * 700] ^= 0xA5;
            let c = ckpt(v, &data);
            containers.insert(v, state.encode_checkpoint(&c, v, 0, &|_| true).unwrap());
            expected = Some(c);
        }
        let last = expected.unwrap();
        // Through the chain only (no store): the plan records the hops.
        let fetch = |v: u64| containers.get(&v).cloned();
        let (out, plan) = materialize_planned(containers[&3].clone(), None, &fetch).unwrap();
        assert_eq!(out, last);
        assert_eq!(out.encode(), last.encode(), "re-encode must be identical");
        assert_eq!(plan.name, "app");
        assert_eq!(plan.version, 3);
        assert!(!plan.hops.is_empty(), "chain walk must record its hops");
        assert!(plan.hops.starts_with(&[2]), "nearest ancestor first: {:?}", plan.hops);
        // Through the store only (no chain fetch): no hops needed.
        let (out, plan) = materialize_planned(
            containers[&3].clone(),
            Some(state.store(0).as_ref()),
            &|_| None,
        )
        .unwrap();
        assert_eq!(out, last);
        assert!(plan.hops.is_empty(), "store fast path takes no hops");
    }

    #[test]
    fn predicted_hops_match_real_walk_for_unit_stride() {
        let (_f, state) = state();
        let mut data = noise(12_288);
        let mut containers: BTreeMap<u64, Vec<u8>> = BTreeMap::new();
        for v in 1..=3u64 {
            data[(v as usize) * 700] ^= 0xA5;
            containers.insert(
                v,
                state.encode_checkpoint(&ckpt(v, &data), v, 0, &|_| true).unwrap(),
            );
        }
        let raw = maybe_decompress(containers[&3].clone()).unwrap();
        let (m, _) = manifest::decode(&raw).unwrap();
        assert_eq!(predicted_hops(&m), vec![2, 1]);
        // A full container predicts no hops.
        let raw1 = maybe_decompress(containers[&1].clone()).unwrap();
        let (m1, _) = manifest::decode(&raw1).unwrap();
        assert!(predicted_hops(&m1).is_empty());
    }

    #[test]
    fn broken_chain_is_an_error_not_wrong_bytes() {
        let (_f, state) = state();
        let mut data = noise(8_192);
        let mut containers: BTreeMap<u64, Vec<u8>> = BTreeMap::new();
        for v in 1..=3u64 {
            containers.insert(
                v,
                state.encode_checkpoint(&ckpt(v, &data), v, 0, &|_| true).unwrap(),
            );
            data[(v as usize) * 900] ^= 0x3C;
        }
        // Lose the middle link and hide the store: v3 must fail loudly,
        // with a typed error naming the missing ancestor.
        let fetch = |v: u64| {
            if v == 2 {
                None
            } else {
                containers.get(&v).cloned()
            }
        };
        let err = materialize(containers[&3].clone(), None, &fetch).unwrap_err();
        match err.downcast_ref::<RestoreError>() {
            Some(RestoreError::ChainBroken { version, missing, .. }) => {
                assert_eq!(*version, 3);
                assert_eq!(*missing, 2);
            }
            other => panic!("expected typed ChainBroken, got {other:?} ({err})"),
        }
        assert!(err.to_string().contains("chain broken"), "{err}");
        // The full base still materializes.
        let out = materialize(containers[&1].clone(), None, &|_| None).unwrap();
        assert_eq!(out.meta.iteration, 1);
    }
}
