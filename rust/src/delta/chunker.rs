//! Content-defined chunking: a FastCDC-style rolling-hash chunker plus the
//! chunk fingerprint the dedup store is keyed by.
//!
//! Boundaries are chosen where a gear rolling hash of the recent bytes
//! matches a mask, so they depend only on *content near the boundary* —
//! an in-place edit shifts or invalidates the chunks covering it and the
//! boundary stream re-synchronizes within a chunk or two, leaving every
//! other chunk (and therefore its fingerprint) untouched. That boundary
//! stability is what makes fingerprint-level dedup effective for
//! iterative applications that mutate a small fraction of their protected
//! state per step.
//!
//! Normalized chunking (FastCDC): below the target average size a stricter
//! mask suppresses early cuts, above it a looser mask forces late ones, so
//! real chunk sizes cluster around `avg` instead of the long-tailed
//! geometric distribution a single mask produces.

use anyhow::{anyhow, Result};

/// Content fingerprint of one chunk: crc32 + length + a 64-bit content
/// hash, packed into 128 bits. Three independent digests must collide
/// simultaneously for two distinct chunks to alias — negligible at
/// checkpoint scale, and cheap enough to verify on every reassembly.
///
/// Both digests go through the word-parallel kernels
/// ([`crate::util::kernels`]): slice-by-16 CRC32 and the 4-lane
/// fingerprint hash. Fingerprints only key the dedup store and manifests
/// written by the same build, so they need self-consistency, not a wire
/// format — the kernel property tests pin each against its scalar
/// baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint(
    /// Packed digest bits: crc32 (high 32) | payload length | hash64.
    pub u128,
);

impl Fingerprint {
    /// Fingerprint a chunk payload.
    pub fn of(data: &[u8]) -> Fingerprint {
        let crc = crate::util::kernels::crc32_wide(data) as u128;
        let len = (data.len() as u32) as u128;
        let h = crate::util::kernels::fp_hash64(data);
        Fingerprint((crc << 96) | (len << 64) | h as u128)
    }

    /// Chunk payload length carried inside the fingerprint.
    pub fn payload_len(&self) -> usize {
        ((self.0 >> 64) as u32) as usize
    }

    /// Canonical 32-hex-digit spelling (store keys, manifests, ledgers).
    pub fn hex(&self) -> String {
        format!("{:032x}", self.0)
    }

    /// Parse the canonical hex spelling produced by [`Fingerprint::hex`].
    pub fn parse(s: &str) -> Result<Fingerprint> {
        u128::from_str_radix(s, 16)
            .map(Fingerprint)
            .map_err(|_| anyhow!("bad fingerprint {s:?}"))
    }
}

/// Gear table: one 64-bit mix per byte value, derived deterministically
/// (splitmix64) so boundaries are stable across processes and versions.
fn gear_table() -> [u64; 256] {
    let mut table = [0u64; 256];
    let mut x: u64 = 0x9E37_79B9_7F4A_7C15;
    for entry in table.iter_mut() {
        x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = x;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        *entry = z ^ (z >> 31);
    }
    table
}

/// The chunker; construction validates the size triplet.
pub struct Chunker {
    min: usize,
    avg: usize,
    max: usize,
    /// Mask used below `avg` (one bit more than the average: cuts rarer).
    mask_strict: u64,
    /// Mask used past `avg` (one bit less: cuts likelier).
    mask_loose: u64,
    table: [u64; 256],
}

impl Chunker {
    /// `avg` must be a power of two (the cut masks derive from its log2),
    /// with `16 <= min <= avg <= max`.
    pub fn new(min: usize, avg: usize, max: usize) -> Result<Chunker> {
        if !(16..=avg).contains(&min) || avg > max {
            return Err(anyhow!(
                "chunker needs 16 <= min <= avg <= max, got {min}/{avg}/{max}"
            ));
        }
        if !avg.is_power_of_two() || avg < 256 {
            return Err(anyhow!(
                "chunker avg must be a power of two >= 256, got {avg}"
            ));
        }
        let bits = avg.trailing_zeros();
        Ok(Chunker {
            min,
            avg,
            max,
            mask_strict: (1u64 << (bits + 1)) - 1,
            mask_loose: (1u64 << (bits - 1)) - 1,
            table: gear_table(),
        })
    }

    /// The configured (min, avg, max) size triplet.
    pub fn sizes(&self) -> (usize, usize, usize) {
        (self.min, self.avg, self.max)
    }

    /// Length of the first chunk of `data` (never 0 for non-empty input).
    ///
    /// The gear recurrence `h = (h << 1) + g[b]` is serial, but unrolling
    /// four positions per iteration removes most of the per-byte loop and
    /// mask-select overhead and lets the four table loads issue in
    /// parallel. Boundaries are bit-identical to [`Self::cut_scalar`]
    /// (property-tested), so chunk streams stay stable across the change.
    pub fn cut(&self, data: &[u8]) -> usize {
        let n = data.len();
        if n <= self.min {
            return n;
        }
        let end = self.max.min(n);
        let norm = self.avg.min(end);
        let mut h: u64 = 0;
        let mut i = self.min;
        // Strict region [min, norm): four candidate boundaries per trip.
        while i + 4 <= norm {
            let h1 = (h << 1).wrapping_add(self.table[data[i] as usize]);
            if h1 & self.mask_strict == 0 {
                return i + 1;
            }
            let h2 = (h1 << 1).wrapping_add(self.table[data[i + 1] as usize]);
            if h2 & self.mask_strict == 0 {
                return i + 2;
            }
            let h3 = (h2 << 1).wrapping_add(self.table[data[i + 2] as usize]);
            if h3 & self.mask_strict == 0 {
                return i + 3;
            }
            h = (h3 << 1).wrapping_add(self.table[data[i + 3] as usize]);
            if h & self.mask_strict == 0 {
                return i + 4;
            }
            i += 4;
        }
        while i < norm {
            h = (h << 1).wrapping_add(self.table[data[i] as usize]);
            if h & self.mask_strict == 0 {
                return i + 1;
            }
            i += 1;
        }
        // Loose region [norm, end): likelier cuts, same unrolling.
        while i + 4 <= end {
            let h1 = (h << 1).wrapping_add(self.table[data[i] as usize]);
            if h1 & self.mask_loose == 0 {
                return i + 1;
            }
            let h2 = (h1 << 1).wrapping_add(self.table[data[i + 1] as usize]);
            if h2 & self.mask_loose == 0 {
                return i + 2;
            }
            let h3 = (h2 << 1).wrapping_add(self.table[data[i + 2] as usize]);
            if h3 & self.mask_loose == 0 {
                return i + 3;
            }
            h = (h3 << 1).wrapping_add(self.table[data[i + 3] as usize]);
            if h & self.mask_loose == 0 {
                return i + 4;
            }
            i += 4;
        }
        while i < end {
            h = (h << 1).wrapping_add(self.table[data[i] as usize]);
            if h & self.mask_loose == 0 {
                return i + 1;
            }
            i += 1;
        }
        end
    }

    /// Byte-serial reference implementation of [`Self::cut`] — the
    /// baseline the unrolled version is property-tested and benched
    /// against.
    pub fn cut_scalar(&self, data: &[u8]) -> usize {
        let n = data.len();
        if n <= self.min {
            return n;
        }
        let end = self.max.min(n);
        let norm = self.avg.min(end);
        let mut h: u64 = 0;
        for (i, &b) in data.iter().enumerate().take(end).skip(self.min) {
            h = (h << 1).wrapping_add(self.table[b as usize]);
            let mask = if i < norm {
                self.mask_strict
            } else {
                self.mask_loose
            };
            if h & mask == 0 {
                return i + 1;
            }
        }
        end
    }

    /// Split a buffer into content-defined chunks; concatenating the
    /// chunks reproduces the buffer exactly. Empty input yields no chunks.
    pub fn split<'a>(&self, mut data: &'a [u8]) -> Vec<&'a [u8]> {
        let mut out = Vec::with_capacity(data.len() / self.avg + 1);
        while !data.is_empty() {
            let cut = self.cut(data);
            out.push(&data[..cut]);
            data = &data[cut..];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunker() -> Chunker {
        Chunker::new(64, 256, 1024).unwrap()
    }

    #[test]
    fn split_is_identity_under_concat() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i * 2654435761) as u8).collect();
        let chunks = chunker().split(&data);
        assert!(chunks.len() > 4, "{} chunks", chunks.len());
        let rebuilt: Vec<u8> = chunks.concat();
        assert_eq!(rebuilt, data);
    }

    #[test]
    fn chunk_sizes_bounded() {
        let data: Vec<u8> = (0..50_000u32).map(|i| (i ^ (i >> 3)) as u8).collect();
        let c = chunker();
        let chunks = c.split(&data);
        for (i, ch) in chunks.iter().enumerate() {
            assert!(ch.len() <= 1024, "chunk {i} is {} bytes", ch.len());
            if i + 1 < chunks.len() {
                assert!(ch.len() > 64, "non-final chunk {i} is {} bytes", ch.len());
            }
        }
    }

    #[test]
    fn boundaries_deterministic_and_content_defined() {
        // Aperiodic filler: a plain `(i * k) as u8` repeats every 256
        // bytes, collapsing the distinct-fingerprint sets this asserts on.
        let data: Vec<u8> = (0..20_000u32)
            .map(|i| (i.wrapping_mul(2_654_435_761) >> 13) as u8)
            .collect();
        let c = chunker();
        let a: Vec<usize> = c.split(&data).iter().map(|s| s.len()).collect();
        let b: Vec<usize> = c.split(&data).iter().map(|s| s.len()).collect();
        assert_eq!(a, b, "same bytes must chunk identically");
        // Two buffers sharing only a suffix must still dedup most of that
        // suffix: boundaries are content-defined, so they re-synchronize
        // shortly after the differing prefixes end.
        let mut other = data.clone();
        for byte in other.iter_mut().take(10_000) {
            *byte = byte.wrapping_add(131);
        }
        let fps = |buf: &[u8]| -> std::collections::BTreeSet<u128> {
            c.split(buf).iter().map(|s| Fingerprint::of(s).0).collect()
        };
        let shared = fps(&data).intersection(&fps(&other)).count();
        assert!(
            shared >= 10,
            "only {shared} shared chunks across a 10 KiB common suffix"
        );
    }

    #[test]
    fn tiny_and_empty_inputs() {
        let c = chunker();
        assert!(c.split(&[]).is_empty());
        let small = vec![9u8; 10];
        let chunks = c.split(&small);
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0], &small[..]);
    }

    #[test]
    fn fingerprint_distinguishes_and_roundtrips() {
        let a = Fingerprint::of(b"hello world");
        let b = Fingerprint::of(b"hello worle");
        assert_ne!(a, b);
        assert_eq!(a, Fingerprint::of(b"hello world"));
        assert_eq!(a.payload_len(), 11);
        assert_eq!(Fingerprint::parse(&a.hex()).unwrap(), a);
        assert!(Fingerprint::parse("xyz").is_err());
    }

    #[test]
    fn bad_size_triplets_rejected() {
        assert!(Chunker::new(8, 256, 1024).is_err()); // min too small
        assert!(Chunker::new(512, 256, 1024).is_err()); // min > avg
        assert!(Chunker::new(64, 300, 1024).is_err()); // avg not 2^n
        assert!(Chunker::new(64, 256, 128).is_err()); // max < avg
    }
}
