//! Delta manifests and the VDLT container that carries them.
//!
//! A manifest is the recipe for one checkpoint version: per region, the
//! ordered fingerprint list of its content-defined chunks, plus the link
//! to the *base* version it was diffed against (`None` for a full
//! checkpoint) and the number of delta links back to the nearest full
//! (`chain_len`, bounded by `DeltaConfig::max_chain`).
//!
//! The VDLT container is what the resilience levels move instead of the
//! raw VCKP once delta is enabled:
//!
//! ```text
//! magic   "VDLT"          4 bytes
//! version u32             format version (1)
//! hlen    u32             header JSON length
//! header  JSON            {"manifest": {...}, "novel": [["fp-hex", len], ...]}
//! body    novel payloads  concatenated in "novel" order
//! crc     u32             CRC32 of everything above
//! ```
//!
//! Only chunks *novel to the manifest chain* ride in the body — unchanged
//! chunks are resolved at restore time from the per-node chunk store or
//! from ancestor containers (see [`super::materialize`]).

use crate::delta::chunker::Fingerprint;
use crate::util::json::{Json, ParseError};
use std::collections::{BTreeSet, HashMap};
use std::fmt;

/// Delta container magic bytes.
pub const VDLT_MAGIC: &[u8; 4] = b"VDLT";
/// Delta container format version.
pub const VDLT_VERSION: u32 = 1;

/// Typed VDLT parse failures. Recovery treats any of these as "this
/// container is unusable, fall back along the chain / to the next level"
/// — none of them may surface as a panic, however hostile the bytes.
#[derive(Debug)]
pub enum ManifestError {
    /// Container shorter than the fixed framing (magic + version + hlen
    /// + trailing CRC).
    TooShort(usize),
    /// Missing `"VDLT"` magic.
    BadMagic,
    /// Whole-container CRC mismatch.
    CrcMismatch {
        /// CRC32 stored in the trailer.
        stored: u32,
        /// CRC32 of the bytes actually present.
        actual: u32,
    },
    /// Unsupported format version.
    BadVersion(u32),
    /// Declared header length overruns the container.
    HeaderTruncated,
    /// Header bytes are not UTF-8.
    HeaderNotUtf8,
    /// Header text is not valid JSON.
    HeaderJson(ParseError),
    /// Header JSON parsed but a field is missing or has the wrong shape.
    Malformed(String),
    /// A novel chunk's declared length overruns the container body.
    ChunkOverrun(String),
    /// A novel chunk's payload does not hash to its declared fingerprint.
    ChunkFingerprint(String),
    /// Body bytes left over after the last declared novel chunk.
    TrailingBytes,
}

impl fmt::Display for ManifestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ManifestError::TooShort(n) => write!(f, "VDLT too short ({n} bytes)"),
            ManifestError::BadMagic => write!(f, "bad VDLT magic"),
            ManifestError::CrcMismatch { stored, actual } => write!(
                f,
                "VDLT CRC mismatch: stored {stored:#010x}, actual {actual:#010x}"
            ),
            ManifestError::BadVersion(v) => write!(f, "unsupported VDLT version {v}"),
            ManifestError::HeaderTruncated => write!(f, "VDLT header truncated"),
            ManifestError::HeaderNotUtf8 => write!(f, "VDLT header not utf-8"),
            ManifestError::HeaderJson(e) => write!(f, "VDLT header: {e}"),
            ManifestError::Malformed(msg) => write!(f, "VDLT manifest: {msg}"),
            ManifestError::ChunkOverrun(fp) => {
                write!(f, "novel chunk {fp} overruns container")
            }
            ManifestError::ChunkFingerprint(fp) => {
                write!(f, "novel chunk payload does not match fingerprint {fp}")
            }
            ManifestError::TrailingBytes => write!(f, "trailing bytes in VDLT body"),
        }
    }
}

impl std::error::Error for ManifestError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ManifestError::HeaderJson(e) => Some(e),
            _ => None,
        }
    }
}

/// One chunk reference inside a region recipe.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkRef {
    /// Chunk fingerprint (dedup-store key).
    pub fp: Fingerprint,
    /// Chunk payload length in bytes.
    pub len: usize,
}

/// Chunk recipe of one protected region, in payload order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RegionChunks {
    /// Protected-region id.
    pub id: u32,
    /// Ordered chunk references reconstructing the region.
    pub chunks: Vec<ChunkRef>,
}

/// The per-(name, rank, version) delta manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DeltaManifest {
    /// Checkpoint name.
    pub name: String,
    /// Originating rank.
    pub rank: usize,
    /// Pipeline version (storage-key component, drives the chain walk).
    pub version: u64,
    /// Application iteration carried in the checkpoint metadata (usually
    /// equal to `version`, but preserved independently so reassembly is
    /// bit-for-bit even when a caller picked different numbering).
    pub iteration: u64,
    /// Version this manifest was diffed against; `None` = full checkpoint.
    pub base: Option<u64>,
    /// Delta links between this version and its nearest full (0 = full).
    pub chain_len: u64,
    /// Regions in checkpoint order.
    pub regions: Vec<RegionChunks>,
}

impl DeltaManifest {
    /// Unique fingerprints referenced by this manifest.
    pub fn fp_set(&self) -> BTreeSet<Fingerprint> {
        self.regions
            .iter()
            .flat_map(|r| r.chunks.iter().map(|c| c.fp))
            .collect()
    }

    /// Total payload bytes the manifest describes.
    pub fn logical_bytes(&self) -> u64 {
        self.regions
            .iter()
            .flat_map(|r| r.chunks.iter())
            .map(|c| c.len as u64)
            .sum()
    }

    /// Is this a full checkpoint (no base link)?
    pub fn is_full(&self) -> bool {
        self.base.is_none()
    }

    /// Serialize for embedding into a VDLT container.
    pub fn to_json(&self) -> Json {
        let regions: Vec<Json> = self
            .regions
            .iter()
            .map(|r| {
                let chunks: Vec<Json> = r
                    .chunks
                    .iter()
                    .map(|c| {
                        Json::Arr(vec![
                            Json::Str(c.fp.hex()),
                            Json::Num(c.len as f64),
                        ])
                    })
                    .collect();
                Json::obj()
                    .set("id", r.id as u64)
                    .set("chunks", Json::Arr(chunks))
            })
            .collect();
        let j = Json::obj()
            .set("name", self.name.as_str())
            .set("rank", self.rank)
            .set("version", self.version)
            .set("iteration", self.iteration)
            .set("chain_len", self.chain_len)
            .set("regions", Json::Arr(regions));
        match self.base {
            Some(b) => j.set("base", b),
            None => j,
        }
    }

    /// Parse a manifest out of a VDLT container header.
    pub fn from_json(j: &Json) -> Result<DeltaManifest, ManifestError> {
        let field = |msg: &str| ManifestError::Malformed(msg.to_string());
        let mut regions = Vec::new();
        for r in j
            .get("regions")
            .and_then(Json::as_arr)
            .ok_or_else(|| field("manifest missing regions"))?
        {
            let id = r
                .get("id")
                .and_then(Json::as_u64)
                .ok_or_else(|| field("region missing id"))? as u32;
            let mut chunks = Vec::new();
            for c in r
                .get("chunks")
                .and_then(Json::as_arr)
                .ok_or_else(|| field("region missing chunks"))?
            {
                chunks.push(chunk_pair(c)?);
            }
            regions.push(RegionChunks { id, chunks });
        }
        Ok(DeltaManifest {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| field("manifest missing name"))?
                .to_string(),
            rank: j
                .get("rank")
                .and_then(Json::as_usize)
                .ok_or_else(|| field("manifest missing rank"))?,
            version: j
                .get("version")
                .and_then(Json::as_u64)
                .ok_or_else(|| field("manifest missing version"))?,
            iteration: j
                .get("iteration")
                .and_then(Json::as_u64)
                .ok_or_else(|| field("manifest missing iteration"))?,
            base: j.get("base").and_then(Json::as_u64),
            chain_len: j.get("chain_len").and_then(Json::as_u64).unwrap_or(0),
            regions,
        })
    }
}

/// Parse one `["fp-hex", len]` pair.
fn chunk_pair(c: &Json) -> Result<ChunkRef, ManifestError> {
    let field = |msg: &str| ManifestError::Malformed(msg.to_string());
    let arr = c.as_arr().ok_or_else(|| field("chunk ref not a pair"))?;
    if arr.len() != 2 {
        return Err(field("chunk ref needs [fp, len]"));
    }
    let hex = arr[0].as_str().ok_or_else(|| field("chunk fp not a string"))?;
    let fp = Fingerprint::parse(hex)
        .map_err(|_| ManifestError::Malformed(format!("bad fingerprint {hex:?}")))?;
    let len = arr[1]
        .as_usize()
        .ok_or_else(|| field("chunk len not a number"))?;
    Ok(ChunkRef { fp, len })
}

/// Does this buffer carry a VDLT container?
pub fn is_delta(buf: &[u8]) -> bool {
    buf.len() >= 4 && &buf[0..4] == VDLT_MAGIC
}

/// Serialize a manifest plus its novel chunk payloads.
pub fn encode(manifest: &DeltaManifest, novel: &[(Fingerprint, &[u8])]) -> Vec<u8> {
    let novel_json: Vec<Json> = novel
        .iter()
        .map(|(fp, data)| {
            Json::Arr(vec![Json::Str(fp.hex()), Json::Num(data.len() as f64)])
        })
        .collect();
    let header = Json::obj()
        .set("manifest", manifest.to_json())
        .set("novel", Json::Arr(novel_json))
        .to_string();
    let hbytes = header.as_bytes();
    let body_len: usize = novel.iter().map(|(_, d)| d.len()).sum();
    let mut out = Vec::with_capacity(4 + 4 + 4 + hbytes.len() + body_len + 4);
    out.extend_from_slice(VDLT_MAGIC);
    out.extend_from_slice(&VDLT_VERSION.to_le_bytes());
    out.extend_from_slice(&(hbytes.len() as u32).to_le_bytes());
    out.extend_from_slice(hbytes);
    for (_, data) in novel {
        out.extend_from_slice(data);
    }
    let crc = crc32fast::hash(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Parse and CRC-validate a VDLT container into its manifest and the
/// chunk payloads it carries.
///
/// Every length in here is attacker-controlled (the CRC only protects
/// against *accidental* corruption), so all offset arithmetic is checked:
/// a hostile declared length yields a typed error, never an overflow or
/// an out-of-bounds slice.
pub fn decode(buf: &[u8]) -> Result<(DeltaManifest, HashMap<Fingerprint, Vec<u8>>), ManifestError> {
    if buf.len() < 16 {
        return Err(ManifestError::TooShort(buf.len()));
    }
    if !is_delta(buf) {
        return Err(ManifestError::BadMagic);
    }
    let stored = u32::from_le_bytes(buf[buf.len() - 4..].try_into().unwrap());
    let actual = crc32fast::hash(&buf[..buf.len() - 4]);
    if stored != actual {
        return Err(ManifestError::CrcMismatch { stored, actual });
    }
    let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    if version != VDLT_VERSION {
        return Err(ManifestError::BadVersion(version));
    }
    let hlen = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
    let hend = 12usize
        .checked_add(hlen)
        .ok_or(ManifestError::HeaderTruncated)?;
    if hend.checked_add(4).map_or(true, |end| buf.len() < end) {
        return Err(ManifestError::HeaderTruncated);
    }
    let header =
        std::str::from_utf8(&buf[12..hend]).map_err(|_| ManifestError::HeaderNotUtf8)?;
    let j = Json::parse(header).map_err(ManifestError::HeaderJson)?;
    let manifest = DeltaManifest::from_json(
        j.get("manifest")
            .ok_or_else(|| ManifestError::Malformed("header missing manifest".to_string()))?,
    )?;
    let body_end = buf.len() - 4;
    let mut chunks = HashMap::new();
    let mut off = hend;
    for entry in j
        .get("novel")
        .and_then(Json::as_arr)
        .ok_or_else(|| ManifestError::Malformed("header missing novel list".to_string()))?
    {
        let c = chunk_pair(entry)?;
        let end = off
            .checked_add(c.len)
            .filter(|&end| end <= body_end)
            .ok_or_else(|| ManifestError::ChunkOverrun(c.fp.hex()))?;
        let data = buf[off..end].to_vec();
        if Fingerprint::of(&data) != c.fp {
            return Err(ManifestError::ChunkFingerprint(c.fp.hex()));
        }
        chunks.insert(c.fp, data);
        off = end;
    }
    if off != body_end {
        return Err(ManifestError::TrailingBytes);
    }
    Ok((manifest, chunks))
}

/// Re-encode a container with every novel payload stripped (manifest kept
/// intact) — the sim's model of a torn flush that persisted the manifest
/// but lost the chunk data.
pub fn strip_payloads(buf: &[u8]) -> Result<Vec<u8>, ManifestError> {
    let (manifest, _) = decode(buf)?;
    Ok(encode(&manifest, &[]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (DeltaManifest, Vec<(Fingerprint, Vec<u8>)>) {
        let a = vec![1u8; 300];
        let b = vec![2u8; 500];
        let fa = Fingerprint::of(&a);
        let fb = Fingerprint::of(&b);
        let manifest = DeltaManifest {
            name: "app".to_string(),
            rank: 3,
            version: 7,
            iteration: 7,
            base: Some(5),
            chain_len: 2,
            regions: vec![
                RegionChunks {
                    id: 0,
                    chunks: vec![
                        ChunkRef { fp: fa, len: 300 },
                        ChunkRef { fp: fb, len: 500 },
                    ],
                },
                RegionChunks {
                    id: 4,
                    chunks: vec![ChunkRef { fp: fa, len: 300 }],
                },
            ],
        };
        (manifest, vec![(fa, a), (fb, b)])
    }

    #[test]
    fn manifest_json_roundtrip() {
        let (m, _) = sample();
        let back = DeltaManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        assert_eq!(m.fp_set().len(), 2);
        assert_eq!(m.logical_bytes(), 1100);
        assert!(!m.is_full());
    }

    #[test]
    fn container_roundtrip() {
        let (m, novel) = sample();
        let pairs: Vec<(Fingerprint, &[u8])> =
            novel.iter().map(|(f, d)| (*f, d.as_slice())).collect();
        let buf = encode(&m, &pairs);
        assert!(is_delta(&buf));
        let (back, chunks) = decode(&buf).unwrap();
        assert_eq!(back, m);
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[&novel[0].0], novel[0].1);
    }

    #[test]
    fn corruption_detected() {
        let (m, novel) = sample();
        let pairs: Vec<(Fingerprint, &[u8])> =
            novel.iter().map(|(f, d)| (*f, d.as_slice())).collect();
        let mut buf = encode(&m, &pairs);
        let mid = buf.len() / 2;
        buf[mid] ^= 0x40;
        let err = decode(&buf).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
        assert!(decode(&buf[..12]).is_err());
    }

    #[test]
    fn hostile_lengths_yield_typed_errors_not_panics() {
        // Build a container whose header declares an absurd novel-chunk
        // length, with a *valid* CRC — the CRC only guards accidental
        // corruption, so the length checks must hold on their own.
        let forge = |novel_len: u64, hlen_override: Option<u32>| -> Vec<u8> {
            let header = format!(
                concat!(
                    "{{\"manifest\":{{\"name\":\"x\",\"rank\":0,\"version\":1,",
                    "\"iteration\":1,\"chain_len\":0,\"regions\":[]}},",
                    "\"novel\":[[\"{:032x}\",{}]]}}"
                ),
                0u128, novel_len
            );
            let hb = header.as_bytes();
            let mut out = Vec::new();
            out.extend_from_slice(VDLT_MAGIC);
            out.extend_from_slice(&VDLT_VERSION.to_le_bytes());
            out.extend_from_slice(
                &hlen_override.unwrap_or(hb.len() as u32).to_le_bytes(),
            );
            out.extend_from_slice(hb);
            let crc = crc32fast::hash(&out);
            out.extend_from_slice(&crc.to_le_bytes());
            out
        };
        // Chunk length far beyond the container, including the value that
        // would overflow `off + len` if the math were unchecked.
        for len in [u64::MAX, (usize::MAX as u64) - 8, 4 << 30] {
            match decode(&forge(len, None)) {
                Err(ManifestError::ChunkOverrun(_)) => {}
                other => panic!("expected ChunkOverrun, got {other:?}"),
            }
        }
        // Inflated header length: the declared end wraps or overruns.
        match decode(&forge(0, Some(u32::MAX))) {
            Err(ManifestError::HeaderTruncated) => {}
            other => panic!("expected HeaderTruncated, got {other:?}"),
        }
    }

    #[test]
    fn strip_keeps_manifest_loses_payloads() {
        let (m, novel) = sample();
        let pairs: Vec<(Fingerprint, &[u8])> =
            novel.iter().map(|(f, d)| (*f, d.as_slice())).collect();
        let buf = encode(&m, &pairs);
        let stripped = strip_payloads(&buf).unwrap();
        assert!(stripped.len() < buf.len());
        let (back, chunks) = decode(&stripped).unwrap();
        assert_eq!(back, m);
        assert!(chunks.is_empty());
    }

    #[test]
    fn empty_manifest_encodes() {
        let m = DeltaManifest {
            name: "x".to_string(),
            rank: 0,
            version: 1,
            iteration: 1,
            base: None,
            chain_len: 0,
            regions: vec![RegionChunks { id: 0, chunks: vec![] }],
        };
        let buf = encode(&m, &[]);
        let (back, chunks) = decode(&buf).unwrap();
        assert_eq!(back, m);
        assert!(chunks.is_empty());
        assert!(back.is_full());
    }
}
