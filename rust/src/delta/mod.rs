//! Incremental deduplicated checkpointing (differential checkpointing in
//! the OpenCHK model's terms — "Extending the OpenCHK Model with Advanced
//! Checkpoint Features").
//!
//! Iterative applications mutate a small fraction of their protected
//! state per step, yet a plain multi-level pipeline moves a full snapshot
//! through every resilience level on every `checkpoint()`. This subsystem
//! cuts the logical→physical byte ratio at the source:
//!
//! - [`chunker`] — a FastCDC-style content-defined chunker with stable,
//!   content-derived boundaries and 128-bit chunk [`Fingerprint`]s.
//! - [`store`] — one refcounted [`ChunkStore`] per node (fingerprint-keyed
//!   chunk payloads on a local [`StorageTier`](crate::storage::StorageTier)),
//!   with a write-ahead GC intent ledger replayed after crashes.
//! - [`manifest`] — per-version delta manifests (ordered fingerprint
//!   recipe + base-version link) and the VDLT container that carries a
//!   manifest plus only its chain-novel chunk payloads.
//! - [`state`] — the runtime-wide [`DeltaState`]: chunk, diff against the
//!   previous version's manifest chain, publish, emit the container.
//! - [`reassemble`] — [`materialize`]: bit-for-bit reconstruction from a
//!   manifest chain at restore time, bounded by periodic forced fulls.
//!
//! The pipeline integration lives in
//! [`modules::delta`](crate::modules::delta): a stage ahead of the level-1
//! capture swaps the context's encoded payload for the VDLT container, so
//! every downstream level (local, partner, erasure, PFS flush — aggregated
//! or direct — and the version registry) moves only novel bytes.
//!
//! ```
//! use veloc::delta::{Chunker, Fingerprint};
//!
//! // Content-defined boundaries re-synchronize after an edit...
//! let chunker = Chunker::new(64, 256, 1024).unwrap();
//! let data = vec![42u8; 8 << 10];
//! let chunks = chunker.split(&data);
//! assert_eq!(chunks.iter().map(|c| c.len()).sum::<usize>(), data.len());
//! // ...and fingerprints round-trip through their canonical spelling.
//! let fp = Fingerprint::of(chunks[0]);
//! assert_eq!(Fingerprint::parse(&fp.hex()).unwrap(), fp);
//! ```

pub mod chunker;
pub mod manifest;
pub mod reassemble;
pub mod state;
pub mod store;

pub use chunker::{Chunker, Fingerprint};
pub use manifest::{
    is_delta, strip_payloads, ChunkRef, DeltaManifest, ManifestError, RegionChunks, VDLT_MAGIC,
};
pub use reassemble::{
    materialize, materialize_planned, predicted_hops, ChainPlan, RestoreError,
};
pub use state::DeltaState;
pub use store::{ChunkStore, DeltaFaultHook, PublishStat, FAULT_GC_INTENT};

use anyhow::{bail, Result};

/// Knobs for incremental deduplicated checkpointing (see the JSON
/// `"delta"` section and the `--delta*` CLI flags).
#[derive(Clone, Debug)]
pub struct DeltaConfig {
    /// Route checkpoints through the chunk/dedup stage.
    pub enabled: bool,
    /// Smallest chunk the cut search may produce.
    pub min_chunk: usize,
    /// Target average chunk size; must be a power of two (the FastCDC cut
    /// masks derive from its log2).
    pub avg_chunk: usize,
    /// Hard upper bound on chunk size.
    pub max_chunk: usize,
    /// Checkpoints per chain: after `max_chain - 1` incremental deltas a
    /// full checkpoint is forced, bounding restore fan-in and GC pinning
    /// (1 = every checkpoint full, i.e. dedup store only, no chains).
    pub max_chain: u64,
}

impl Default for DeltaConfig {
    fn default() -> Self {
        DeltaConfig {
            enabled: false,
            min_chunk: 2 << 10,
            avg_chunk: 8 << 10,
            max_chunk: 64 << 10,
            max_chain: 8,
        }
    }
}

impl DeltaConfig {
    /// Reject size/chain combinations the chunker or recovery could only
    /// patch up silently. Called by `VelocConfig::validate`.
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        if self.min_chunk < 64 {
            bail!(
                "delta.min_chunk = {} is below the 64-byte minimum",
                self.min_chunk
            );
        }
        if !(self.min_chunk <= self.avg_chunk && self.avg_chunk <= self.max_chunk) {
            bail!(
                "delta chunk sizes must satisfy min <= avg <= max, got {}/{}/{}",
                self.min_chunk,
                self.avg_chunk,
                self.max_chunk
            );
        }
        if !self.avg_chunk.is_power_of_two() || self.avg_chunk < 256 {
            bail!(
                "delta.avg_chunk must be a power of two >= 256 (the FastCDC \
                 cut masks derive from it), got {}",
                self.avg_chunk
            );
        }
        if self.max_chunk > 64 << 20 {
            bail!("delta.max_chunk = {} exceeds the 64 MiB bound", self.max_chunk);
        }
        if self.max_chain == 0 {
            bail!("delta.max_chain must be >= 1 (1 = every checkpoint full)");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_disabled_and_valid_when_enabled() {
        let c = DeltaConfig::default();
        assert!(!c.enabled);
        assert!(c.validate().is_ok());
        let on = DeltaConfig {
            enabled: true,
            ..Default::default()
        };
        assert!(on.validate().is_ok());
    }

    #[test]
    fn bad_configs_rejected() {
        let base = DeltaConfig {
            enabled: true,
            ..Default::default()
        };
        let mut c = base.clone();
        c.min_chunk = 16;
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.avg_chunk = 3000; // not a power of two
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.max_chunk = c.avg_chunk / 2;
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.max_chain = 0;
        assert!(c.validate().is_err());
        // Disabled configs skip validation entirely.
        let mut c = base;
        c.enabled = false;
        c.avg_chunk = 3000;
        assert!(c.validate().is_ok());
    }
}
