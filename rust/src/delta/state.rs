//! Runtime-wide delta state: the chunker, one [`ChunkStore`] per node and
//! the manifest history every incremental checkpoint diffs against.
//!
//! [`DeltaState::encode_checkpoint`] is the hot path, run by the pipeline's
//! delta stage before the level-1 capture: chunk every region, diff the
//! fingerprints against the previous version's manifest *chain*, publish
//! the chunks into the node store (refcounted; only payloads not already
//! stored are written) and emit the VDLT container that the resilience
//! levels move instead of the full VCKP. Chain length is bounded by
//! [`DeltaConfig::max_chain`](super::DeltaConfig::max_chain): once
//! `max_chain - 1` deltas ride on a full, the next checkpoint is forced
//! full again, which bounds both restore fan-in and how many old versions
//! garbage collection must pin.

use crate::delta::chunker::{Chunker, Fingerprint};
use crate::delta::manifest::{self, ChunkRef, DeltaManifest, RegionChunks};
use crate::delta::store::{ChunkStore, DeltaFaultHook};
use crate::delta::DeltaConfig;
use crate::metrics::Metrics;
use crate::storage::StorageFabric;
use crate::util::bytes::Checkpoint;
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::{Arc, Mutex};

/// One (name, rank)'s manifest history: version -> manifest.
type ManifestHistory = BTreeMap<u64, Arc<DeltaManifest>>;

/// Runtime-wide incremental-dedup state: the chunker, one refcounted
/// chunk store per node, and the manifest histories chain diffs and GC
/// walk (see the [module docs](crate::delta)).
pub struct DeltaState {
    cfg: DeltaConfig,
    chunker: Chunker,
    /// One chunk store per node, backed by the node's largest local tier.
    stores: Vec<Arc<ChunkStore>>,
    /// (name, rank) -> manifest history, for chain diffing and GC.
    manifests: Mutex<HashMap<(String, usize), ManifestHistory>>,
    metrics: Option<Arc<Metrics>>,
}

impl DeltaState {
    /// Build the delta state over a fabric: validates the config and
    /// places one chunk store on each node's largest local tier.
    pub fn new(
        cfg: DeltaConfig,
        fabric: &StorageFabric,
        metrics: Option<Arc<Metrics>>,
    ) -> Result<Arc<DeltaState>> {
        cfg.validate()?;
        let chunker = Chunker::new(cfg.min_chunk, cfg.avg_chunk, cfg.max_chunk)?;
        let mut stores = Vec::with_capacity(fabric.nodes());
        for node in 0..fabric.nodes() {
            let tier = fabric
                .local_tiers(node)
                .last()
                .ok_or_else(|| anyhow!("node {node} has no local tier for the chunk store"))?;
            stores.push(ChunkStore::new(Arc::clone(tier), node, metrics.clone()));
        }
        Ok(Arc::new(DeltaState {
            cfg,
            chunker,
            stores,
            manifests: Mutex::new(HashMap::new()),
            metrics,
        }))
    }

    /// The delta knobs this state runs under.
    pub fn config(&self) -> &DeltaConfig {
        &self.cfg
    }

    /// One node's chunk store.
    pub fn store(&self, node: usize) -> &Arc<ChunkStore> {
        &self.stores[node]
    }

    /// Install (or clear) the fault hook on every node store — scenario
    /// engine instrumentation, never set in production.
    pub fn set_fault_hook(&self, hook: Option<DeltaFaultHook>) {
        for s in &self.stores {
            s.set_fault_hook(hook.clone());
        }
    }

    /// Replay any pending GC intents (respawn path). Returns how many
    /// stores had an unapplied intent.
    pub fn recover_all(&self) -> u64 {
        let mut replayed = 0;
        for s in &self.stores {
            if s.replay_intent().unwrap_or(false) {
                replayed += 1;
            }
        }
        replayed
    }

    /// Model a node failure: the node's chunk-store tier was wiped, so
    /// its in-memory counts are void, and the manifest history of the
    /// node's ranks must be dropped — their next checkpoint then emits a
    /// self-contained full (fresh-process semantics) instead of a delta
    /// whose chain and chunks died with the node.
    pub fn fail_node(&self, node: usize, ranks: &[usize]) {
        self.stores[node].reset();
        let mut g = self.manifests.lock().unwrap();
        g.retain(|(_, rank), _| !ranks.contains(rank));
    }

    /// Model a full-system failure: every node store and every manifest
    /// history is lost.
    pub fn fail_all(&self) {
        for s in &self.stores {
            s.reset();
        }
        self.manifests.lock().unwrap().clear();
    }

    /// Does any rank still hold an in-memory manifest for this version?
    /// (GC uses this to tell "full checkpoint, no ancestors" apart from
    /// "delta checkpoint whose chain knowledge died with a node".)
    pub fn has_manifest(&self, name: &str, version: u64) -> bool {
        let g = self.manifests.lock().unwrap();
        g.iter()
            .any(|((n, _), h)| n == name && h.contains_key(&version))
    }

    /// Live manifests of one (name, rank), oldest first.
    pub fn manifests_of(&self, name: &str, rank: usize) -> Vec<Arc<DeltaManifest>> {
        let g = self.manifests.lock().unwrap();
        g.get(&(name.to_string(), rank))
            .map(|m| m.values().cloned().collect())
            .unwrap_or_default()
    }

    /// Chain ancestors (strictly older versions a restore of `version`
    /// may need), unioned across ranks. Used by version GC to pin
    /// containers that newer deltas still reference.
    pub fn chain_ancestors(&self, name: &str, version: u64) -> BTreeSet<u64> {
        let g = self.manifests.lock().unwrap();
        let mut out = BTreeSet::new();
        for ((n, _), history) in g.iter() {
            if n != name {
                continue;
            }
            let mut cur = history.get(&version).and_then(|m| m.base);
            while let Some(v) = cur {
                if !out.insert(v) {
                    break;
                }
                cur = history.get(&v).and_then(|m| m.base);
            }
        }
        out
    }

    /// Retire one rank's manifest of a version: forget it and drop its
    /// chunk references (reclaiming payloads that hit zero).
    pub fn retire(&self, name: &str, version: u64, rank: usize, node: usize) -> Result<()> {
        let removed = {
            let mut g = self.manifests.lock().unwrap();
            g.get_mut(&(name.to_string(), rank))
                .and_then(|m| m.remove(&version))
        };
        if let Some(m) = removed {
            self.store(node).release(&m.fp_set(), rank)?;
        }
        Ok(())
    }

    /// Chunk + dedup one checkpoint; returns the VDLT container to send
    /// down the pipeline in place of the raw VCKP.
    ///
    /// `base_ok` reports whether a candidate base version's container
    /// actually landed anywhere (the pipeline stage probes the level-1
    /// copy). A version whose pipeline failed after the delta stage would
    /// otherwise linger in the history as a *phantom link*: later deltas
    /// would base on it, omit its chunks, and a remote chain restore
    /// would break on a version no level ever stored. A rejected base
    /// forces a self-contained full and evicts the phantom manifest.
    pub fn encode_checkpoint(
        &self,
        ckpt: &Checkpoint,
        version: u64,
        node: usize,
        base_ok: &dyn Fn(u64) -> bool,
    ) -> Result<Vec<u8>> {
        let name = ckpt.meta.name.clone();
        let rank = ckpt.meta.rank;

        // Chunk every region; remember one payload slice per fingerprint.
        let mut regions = Vec::with_capacity(ckpt.regions.len());
        let mut payloads: BTreeMap<Fingerprint, &[u8]> = BTreeMap::new();
        for r in &ckpt.regions {
            let mut chunks = Vec::new();
            for piece in self.chunker.split(&r.data) {
                let fp = Fingerprint::of(piece);
                chunks.push(ChunkRef {
                    fp,
                    len: piece.len(),
                });
                payloads.entry(fp).or_insert(piece);
            }
            regions.push(RegionChunks { id: r.id, chunks });
        }

        // Base selection: the previous version, unless the chain budget is
        // spent, the candidate was never stored, or its in-memory chain is
        // broken (fresh process) — then force a self-contained full. The
        // lock covers only the map walks; the base-durability probe and
        // all tier I/O run outside it so concurrent ranks' blocking delta
        // stages do not serialize on one mutex.
        let (prev, chain_manifests) = {
            let g = self.manifests.lock().unwrap();
            let history = g.get(&(name.clone(), rank));
            let prev = history.and_then(|h| {
                h.range(..version).next_back().map(|(_, m)| Arc::clone(m))
            });
            let chain = match (history, &prev) {
                (Some(h), Some(p)) => Self::chain_manifests(h, p),
                _ => None,
            };
            (prev, chain)
        };
        let (base, chain_len, chain_fps, phantom) = match prev {
            // Chain budget spent: forced full, no probe needed.
            Some(p) if p.chain_len + 1 >= self.cfg.max_chain => {
                (None, 0, BTreeSet::new(), None)
            }
            // The candidate base was never stored: force a full and
            // schedule the phantom manifest for eviction.
            Some(p) if !base_ok(p.version) => (None, 0, BTreeSet::new(), Some(p)),
            Some(p) => match chain_manifests {
                Some(ms) => {
                    let mut fps = BTreeSet::new();
                    for m in &ms {
                        fps.extend(m.fp_set());
                    }
                    (Some(p.version), p.chain_len + 1, fps, None)
                }
                None => (None, 0, BTreeSet::new(), None),
            },
            None => (None, 0, BTreeSet::new(), None),
        };
        if let Some(p) = phantom {
            let _ = self.retire(&name, p.version, rank, node);
        }

        let manifest = DeltaManifest {
            name,
            rank,
            version,
            iteration: ckpt.meta.iteration,
            base,
            chain_len,
            regions,
        };

        // Novel payloads (not resolvable from the chain), in deterministic
        // first-appearance order.
        let mut seen = BTreeSet::new();
        let mut novel: Vec<(Fingerprint, &[u8])> = Vec::new();
        for r in &manifest.regions {
            for c in &r.chunks {
                if chain_fps.contains(&c.fp) || !seen.insert(c.fp) {
                    continue;
                }
                novel.push((c.fp, payloads[&c.fp]));
            }
        }

        self.store(node).publish(&payloads)?;
        let container = manifest::encode(&manifest, &novel);

        if let Some(m) = &self.metrics {
            m.incr("delta.bytes.logical", manifest.logical_bytes());
            m.incr("delta.bytes.physical", container.len() as u64);
            m.incr("delta.chunks.total", payloads.len() as u64);
            m.incr("delta.chunks.novel", novel.len() as u64);
            m.incr(
                if manifest.is_full() {
                    "delta.ckpt.full"
                } else {
                    "delta.ckpt.incremental"
                },
                1,
            );
        }
        let key = (manifest.name.clone(), rank);
        let superseded = self
            .manifests
            .lock()
            .unwrap()
            .entry(key)
            .or_default()
            .insert(version, Arc::new(manifest));
        // A re-checkpointed version (caller retried the same number)
        // replaces its manifest; drop the old one's references or its
        // chunks would leak forever. Newer manifests hold their own refs
        // for everything they reference, so this can never free a chunk
        // a live chain still needs.
        if let Some(old) = superseded {
            let _ = self.store(node).release(&old.fp_set(), rank);
        }
        Ok(container)
    }

    /// Every manifest reachable from `from` through its base chain
    /// (inclusive), or `None` when a link is missing from the in-memory
    /// history. Cheap map walks only — safe to call under the lock.
    fn chain_manifests(
        history: &ManifestHistory,
        from: &Arc<DeltaManifest>,
    ) -> Option<Vec<Arc<DeltaManifest>>> {
        let mut out = Vec::new();
        let mut cur = Some(from.version);
        while let Some(v) = cur {
            let m = history.get(&v)?;
            out.push(Arc::clone(m));
            cur = m.base;
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::FabricConfig;

    fn fabric() -> StorageFabric {
        StorageFabric::build(&FabricConfig {
            nodes: 2,
            ..Default::default()
        })
        .unwrap()
    }

    fn cfg() -> DeltaConfig {
        DeltaConfig {
            enabled: true,
            min_chunk: 64,
            avg_chunk: 256,
            max_chunk: 1024,
            max_chain: 3,
        }
    }

    fn ckpt(version: u64, data: &[u8]) -> Checkpoint {
        let mut c = Checkpoint::new("app", 0, version);
        c.push_region(0, data.to_vec());
        c
    }

    /// Aperiodic filler — periodic patterns dedup within one checkpoint
    /// and would skew the size assertions.
    fn noise(n: usize) -> Vec<u8> {
        (0..n as u32)
            .map(|i| (i.wrapping_mul(2_654_435_761) >> 13) as u8)
            .collect()
    }

    #[test]
    fn first_checkpoint_is_full_then_deltas_then_forced_full() {
        let f = fabric();
        let state = DeltaState::new(cfg(), &f, None).unwrap();
        let mut data = noise(16_384);
        let full = state.encode_checkpoint(&ckpt(1, &data), 1, 0, &|_| true).unwrap();
        data[100] ^= 0xFF;
        let d2 = state.encode_checkpoint(&ckpt(2, &data), 2, 0, &|_| true).unwrap();
        data[9000] ^= 0xFF;
        let d3 = state.encode_checkpoint(&ckpt(3, &data), 3, 0, &|_| true).unwrap();
        data[12_000] ^= 0xFF;
        let f4 = state.encode_checkpoint(&ckpt(4, &data), 4, 0, &|_| true).unwrap();

        let (m1, _) = manifest::decode(&full).unwrap();
        let (m2, _) = manifest::decode(&d2).unwrap();
        let (m3, _) = manifest::decode(&d3).unwrap();
        let (m4, _) = manifest::decode(&f4).unwrap();
        assert!(m1.is_full());
        assert_eq!(m2.base, Some(1));
        assert_eq!(m2.chain_len, 1);
        assert_eq!(m3.base, Some(2));
        assert_eq!(m3.chain_len, 2);
        assert!(m4.is_full(), "chain budget of 3 forces a full at the 4th");
        // Deltas are far smaller than fulls.
        assert!(d2.len() * 4 < full.len(), "{} vs {}", d2.len(), full.len());
        assert!(d3.len() * 4 < full.len());
    }

    #[test]
    fn chain_ancestors_and_retire_release_refcounts() {
        let f = fabric();
        let state = DeltaState::new(cfg(), &f, None).unwrap();
        let mut data = noise(8_192);
        for v in 1..=3u64 {
            state.encode_checkpoint(&ckpt(v, &data), v, 0, &|_| true).unwrap();
            data[(v as usize) * 500] ^= 0x55;
        }
        assert_eq!(
            state.chain_ancestors("app", 3),
            [1u64, 2].into_iter().collect::<BTreeSet<_>>()
        );
        assert!(state.chain_ancestors("app", 1).is_empty());
        // Retiring v1 releases refs but shared chunks stay (v2/v3 refs).
        let m1 = state.manifests_of("app", 0)[0].clone();
        state.retire("app", 1, 0, 0).unwrap();
        assert_eq!(state.manifests_of("app", 0).len(), 2);
        let shared: Vec<_> = m1.fp_set().into_iter().collect();
        assert!(
            shared.iter().any(|fp| state.store(0).contains(fp)),
            "chunks still referenced by v2/v3 must survive v1's retirement"
        );
    }

    #[test]
    fn phantom_base_rejected_and_evicted() {
        let f = fabric();
        let state = DeltaState::new(cfg(), &f, None).unwrap();
        let data = noise(8_192);
        state
            .encode_checkpoint(&ckpt(1, &data), 1, 0, &|_| true)
            .unwrap();
        // v1's container never landed anywhere (pipeline failed after the
        // delta stage): v2 must refuse the phantom base, emit a full and
        // evict the dangling manifest.
        let c2 = state
            .encode_checkpoint(&ckpt(2, &data), 2, 0, &|_| false)
            .unwrap();
        let (m2, _) = manifest::decode(&c2).unwrap();
        assert!(m2.is_full(), "phantom base must not be used");
        let live = state.manifests_of("app", 0);
        assert_eq!(live.len(), 1, "phantom manifest must be evicted");
        assert_eq!(live[0].version, 2);
    }

    #[test]
    fn fresh_state_forces_full_after_history_loss() {
        let f = fabric();
        let data = noise(4_096);
        let state = DeltaState::new(cfg(), &f, None).unwrap();
        state.encode_checkpoint(&ckpt(1, &data), 1, 0, &|_| true).unwrap();
        // A respawned process builds a fresh state over the same fabric.
        let state2 = DeltaState::new(cfg(), &f, None).unwrap();
        let c = state2.encode_checkpoint(&ckpt(2, &data), 2, 0, &|_| true).unwrap();
        let (m, _) = manifest::decode(&c).unwrap();
        assert!(m.is_full(), "no in-memory history: must emit a full");
    }
}
