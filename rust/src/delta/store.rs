//! Per-node refcounted chunk store.
//!
//! Unique chunk payloads live as fingerprint-keyed objects on one local
//! [`StorageTier`] of the node (the largest, so dedup state does not evict
//! level-1 copies from the fast tier). Reference counts track how many
//! live manifests name each chunk; when the version registry retires a
//! version, [`ChunkStore::release`] decrements and deletes chunks that hit
//! zero.
//!
//! ## Crash consistency: the GC intent ledger
//!
//! A release is not atomic against process death: the writer could die
//! after deciding to free chunks but before the deletions and the ledger
//! snapshot land. The store therefore write-ahead-logs every release:
//!
//! 1. persist the *intent* (`{seq, fps}`) on the tier,
//! 2. apply the decrefs in memory and delete zero-ref chunk objects,
//! 3. persist the refcount *ledger* snapshot (`{seq, refs}`),
//! 4. delete the intent.
//!
//! A crash between 1 and 4 leaves the intent durable. Replay
//! ([`ChunkStore::replay_intent`], run by the next release on the node or
//! by [`super::DeltaState::recover_all`] after a respawn) compares the
//! intent's sequence number with the ledger's: an already-applied intent
//! (ledger seq >= intent seq) is simply cleared; an unapplied one resets
//! the in-memory counts to the durable ledger snapshot, re-applies the
//! decrefs exactly once and re-persists — idempotent under repeated
//! crashes in the same window.
//!
//! Cost note: the ledger snapshot is also persisted on every publish so
//! that a replay never resets counts to a state missing recent increfs.
//! That write is O(unique chunks in the store) — fine at the modeled
//! scale this repo targets; a production port would append per-publish
//! ref deltas to a journal and snapshot only at release time.

use crate::delta::chunker::Fingerprint;
use crate::metrics::Metrics;
use crate::storage::StorageTier;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::{Arc, Mutex};

/// Named crash window inside [`ChunkStore::release`]: the GC intent is
/// durable, the decrefs/deletions are not. The scenario engine lands
/// simulated failures here; production installs no hook.
pub const FAULT_GC_INTENT: &str = "delta.gc.post_intent";

/// Fault hook consulted at named points; arguments are the point name and
/// the rank performing the operation. Returning `true` means the failure
/// lands there: the operation stops as a crashed writer would.
pub type DeltaFaultHook = Arc<dyn Fn(&str, usize) -> bool + Send + Sync>;

#[derive(Default)]
struct StoreInner {
    refs: HashMap<Fingerprint, u64>,
    /// Sequence number of the last *applied* GC. The ledger always
    /// persists this value — never a provisional one — so an intent with
    /// seq > ledger seq is exactly "durable but not applied", no matter
    /// how many publishes land between a crashed release and its replay.
    applied_seq: u64,
}

/// Outcome of one [`ChunkStore::publish`].
#[derive(Clone, Copy, Debug, Default)]
pub struct PublishStat {
    /// Chunks whose payload was actually written (not already stored).
    pub novel_chunks: u64,
    /// Bytes those novel chunks moved to the backing tier.
    pub novel_bytes: u64,
}

/// One node's refcounted, fingerprint-keyed chunk store (payloads live
/// on a local tier; GC runs under a crash-replayable intent ledger).
pub struct ChunkStore {
    tier: Arc<StorageTier>,
    node: usize,
    inner: Mutex<StoreInner>,
    metrics: Option<Arc<Metrics>>,
    fault_hook: Mutex<Option<DeltaFaultHook>>,
}

impl ChunkStore {
    /// Build a store over a backing tier, resuming any durable ledger
    /// state (and replaying a pending GC intent) found there.
    pub fn new(
        tier: Arc<StorageTier>,
        node: usize,
        metrics: Option<Arc<Metrics>>,
    ) -> Arc<ChunkStore> {
        let store = Arc::new(ChunkStore {
            tier,
            node,
            inner: Mutex::new(StoreInner::default()),
            metrics,
            fault_hook: Mutex::new(None),
        });
        // A store built over a tier with prior GC history must not start
        // its sequence below the durable ledger's, or publishes would
        // regress the persisted seq and a pending intent could read as
        // already applied.
        if let Ok((seq, _)) = store.load_ledger() {
            store.inner.lock().unwrap().applied_seq = seq;
        }
        // And a pending intent must be settled *before* this store's
        // first publish snapshots the ledger, or the stale decrefs would
        // later be applied against refcounts they no longer describe.
        let _ = store.replay_intent();
        store
    }

    /// The node this store belongs to.
    pub fn node(&self) -> usize {
        self.node
    }

    /// Install (or clear) the fault hook — scenario-engine
    /// instrumentation, never set in production.
    pub fn set_fault_hook(&self, hook: Option<DeltaFaultHook>) {
        *self.fault_hook.lock().unwrap() = hook;
    }

    fn fault_at(&self, point: &str, rank: usize) -> bool {
        let hook = self.fault_hook.lock().unwrap().clone();
        hook.map(|h| h(point, rank)).unwrap_or(false)
    }

    fn chunk_key(fp: &Fingerprint) -> String {
        format!("delta.c.{}", fp.hex())
    }

    fn ledger_key(&self) -> String {
        format!("delta.n{}.ledger", self.node)
    }

    fn intent_key(&self) -> String {
        format!("delta.n{}.gcintent", self.node)
    }

    /// Absorb one manifest's chunks: write payloads not yet stored and
    /// take one reference per unique fingerprint. Persists the ledger so
    /// a later replay sees counts current up to this publish.
    pub fn publish(&self, chunks: &BTreeMap<Fingerprint, &[u8]>) -> Result<PublishStat> {
        let mut stat = PublishStat::default();
        // Payload writes run outside the store mutex (they dominate the
        // blocking delta stage; chunk objects are content-addressed, so a
        // concurrent publish of the same fingerprint is idempotent).
        for (fp, data) in chunks {
            let key = Self::chunk_key(fp);
            if !self.tier.exists(&key) {
                self.tier.put(&key, data)?;
                stat.novel_chunks += 1;
                stat.novel_bytes += data.len() as u64;
            }
        }
        let mut inner = self.inner.lock().unwrap();
        for (fp, data) in chunks {
            // Re-check under the lock: a concurrent release may have
            // reclaimed a just-written chunk before our references took
            // hold (release deletes only while holding this mutex).
            let key = Self::chunk_key(fp);
            let count = inner.refs.entry(*fp).or_insert(0);
            if *count == 0 && !self.tier.exists(&key) {
                self.tier.put(&key, data)?;
                stat.novel_chunks += 1;
                stat.novel_bytes += data.len() as u64;
            }
            *count += 1;
        }
        self.persist_ledger(&inner)?;
        Ok(stat)
    }

    /// Fetch a chunk payload, verifying it against its fingerprint.
    pub fn get(&self, fp: &Fingerprint) -> Option<Vec<u8>> {
        let (data, _) = self.tier.get(&Self::chunk_key(fp))?;
        if Fingerprint::of(&data) != *fp {
            return None;
        }
        Some(data)
    }

    /// Is the chunk payload present on the backing tier?
    pub fn contains(&self, fp: &Fingerprint) -> bool {
        self.tier.exists(&Self::chunk_key(fp))
    }

    /// Model the owning node's failure: the backing tier was wiped, so
    /// the in-memory counts are meaningless — forget them, or later
    /// publishes would skip re-writing payloads the wipe destroyed.
    pub fn reset(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.refs.clear();
        inner.applied_seq = 0;
    }

    /// Current reference count of a fingerprint (0 = absent).
    pub fn refcount(&self, fp: &Fingerprint) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .refs
            .get(fp)
            .copied()
            .unwrap_or(0)
    }

    /// Drop one reference per fingerprint (a manifest retired); deletes
    /// chunks whose count hits zero. `rank` identifies the GC writer for
    /// fault-injection purposes. Returns the number of chunks reclaimed.
    pub fn release(&self, fps: &BTreeSet<Fingerprint>, rank: usize) -> Result<u64> {
        self.replay_intent()?;
        let mut inner = self.inner.lock().unwrap();
        // The intent gets the *next* sequence number, but `applied_seq`
        // only advances after the decrefs land — publishes in between
        // persist the old value, keeping the intent recognizably pending.
        // (`applied_seq` can never trail the durable ledger: new() syncs
        // it at construction and replay/release keep it current.)
        let iseq = inner.applied_seq + 1;
        let intent = Json::obj()
            .set("seq", iseq)
            .set(
                "fps",
                Json::Arr(fps.iter().map(|f| Json::Str(f.hex())).collect()),
            )
            .to_string();
        self.tier.put(&self.intent_key(), intent.as_bytes())?;
        if self.fault_at(FAULT_GC_INTENT, rank) {
            // Simulated writer death: intent durable, decrefs not applied.
            return Ok(0);
        }
        let deleted = Self::apply_decrefs(&self.tier, &mut inner, fps);
        inner.applied_seq = iseq;
        self.persist_ledger(&inner)?;
        self.tier.delete(&self.intent_key());
        if let Some(m) = &self.metrics {
            m.incr("delta.chunks.gc", deleted);
        }
        Ok(deleted)
    }

    fn apply_decrefs(
        tier: &Arc<StorageTier>,
        inner: &mut StoreInner,
        fps: &BTreeSet<Fingerprint>,
    ) -> u64 {
        let mut deleted = 0;
        for fp in fps {
            match inner.refs.get_mut(fp) {
                Some(c) if *c > 1 => *c -= 1,
                Some(_) => {
                    inner.refs.remove(fp);
                    if tier.delete(&Self::chunk_key(fp)) {
                        deleted += 1;
                    }
                }
                None => {}
            }
        }
        deleted
    }

    /// Replay a pending GC intent left by a crashed writer. Returns true
    /// when an unapplied intent was found and applied.
    pub fn replay_intent(&self) -> Result<bool> {
        let Some((bytes, _)) = self.tier.get(&self.intent_key()) else {
            return Ok(false);
        };
        // A torn/corrupt intent must not wedge reclamation forever (every
        // release starts with a replay): quarantine it instead. Dropping
        // a corrupt intent leaks at most its one decref set — bounded —
        // versus erroring out of every future GC on the node.
        let parsed: Option<(u64, BTreeSet<Fingerprint>)> = (|| {
            let j = Json::parse(std::str::from_utf8(&bytes).ok()?).ok()?;
            let seq = j.get("seq").and_then(Json::as_u64)?;
            let mut fps = BTreeSet::new();
            for f in j.get("fps").and_then(Json::as_arr).unwrap_or(&[]) {
                fps.insert(Fingerprint::parse(f.as_str()?).ok()?);
            }
            Some((seq, fps))
        })();
        let Some((iseq, fps)) = parsed else {
            self.tier.delete(&self.intent_key());
            if let Some(m) = &self.metrics {
                m.incr("delta.gc.intent_corrupt", 1);
            }
            return Ok(false);
        };
        let mut inner = self.inner.lock().unwrap();
        let (lseq, lrefs) = self.load_ledger()?;
        if lseq >= iseq {
            // The crashed writer got as far as persisting the ledger: the
            // intent is already applied, only the cleanup is missing.
            inner.applied_seq = inner.applied_seq.max(lseq);
            self.tier.delete(&self.intent_key());
            return Ok(false);
        }
        // A respawned writer lost the in-memory counts; restart from the
        // durable snapshot and apply the interrupted GC exactly once.
        inner.refs = lrefs;
        Self::apply_decrefs(&self.tier, &mut inner, &fps);
        inner.applied_seq = iseq;
        self.persist_ledger(&inner)?;
        self.tier.delete(&self.intent_key());
        if let Some(m) = &self.metrics {
            m.incr("delta.gc.replays", 1);
        }
        Ok(true)
    }

    fn persist_ledger(&self, inner: &StoreInner) -> Result<()> {
        // BTreeMap ordering keeps the snapshot deterministic.
        let sorted: BTreeMap<&Fingerprint, &u64> = inner.refs.iter().collect();
        let refs: Vec<Json> = sorted
            .into_iter()
            .map(|(fp, n)| Json::Arr(vec![Json::Str(fp.hex()), Json::Num(*n as f64)]))
            .collect();
        let ledger = Json::obj()
            .set("seq", inner.applied_seq)
            .set("refs", Json::Arr(refs))
            .to_string();
        self.tier.put(&self.ledger_key(), ledger.as_bytes())?;
        Ok(())
    }

    fn load_ledger(&self) -> Result<(u64, HashMap<Fingerprint, u64>)> {
        let Some((bytes, _)) = self.tier.get(&self.ledger_key()) else {
            return Ok((0, HashMap::new()));
        };
        let j = Json::parse(std::str::from_utf8(&bytes)?)
            .map_err(|e| anyhow!("delta ledger: {e}"))?;
        let seq = j.get("seq").and_then(Json::as_u64).unwrap_or(0);
        let mut refs = HashMap::new();
        for entry in j.get("refs").and_then(Json::as_arr).unwrap_or(&[]) {
            let arr = entry
                .as_arr()
                .ok_or_else(|| anyhow!("ledger entry not a pair"))?;
            if arr.len() != 2 {
                continue;
            }
            let fp = Fingerprint::parse(
                arr[0]
                    .as_str()
                    .ok_or_else(|| anyhow!("ledger fp not a string"))?,
            )?;
            let n = arr[1].as_u64().unwrap_or(0);
            if n > 0 {
                refs.insert(fp, n);
            }
        }
        Ok((seq, refs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{presets, TimeMode};
    use std::sync::atomic::{AtomicBool, Ordering};

    fn store() -> Arc<ChunkStore> {
        let tier = StorageTier::memory(presets::ssd(1 << 30), TimeMode::Model);
        ChunkStore::new(tier, 0, None)
    }

    fn fps_of(chunks: &[&[u8]]) -> (BTreeMap<Fingerprint, &'static [u8]>, BTreeSet<Fingerprint>) {
        // Helper only used with 'static test data.
        let mut map = BTreeMap::new();
        let mut set = BTreeSet::new();
        for c in chunks {
            let data: &'static [u8] = Box::leak(c.to_vec().into_boxed_slice());
            let fp = Fingerprint::of(data);
            map.insert(fp, data);
            set.insert(fp);
        }
        (map, set)
    }

    #[test]
    fn publish_dedups_and_counts() {
        let s = store();
        let (map, set) = fps_of(&[&b"aaaa"[..], &b"bbbb"[..]]);
        let stat = s.publish(&map).unwrap();
        assert_eq!(stat.novel_chunks, 2);
        let stat = s.publish(&map).unwrap();
        assert_eq!(stat.novel_chunks, 0, "second manifest re-stores nothing");
        for fp in &set {
            assert_eq!(s.refcount(fp), 2);
            assert!(s.contains(fp));
            assert_eq!(s.get(fp).unwrap(), fp_payload(&map, fp));
        }
    }

    fn fp_payload<'a>(map: &BTreeMap<Fingerprint, &'a [u8]>, fp: &Fingerprint) -> &'a [u8] {
        map.get(fp).unwrap()
    }

    #[test]
    fn release_reclaims_at_zero_refs() {
        let s = store();
        let (map, set) = fps_of(&[&b"cccc"[..], &b"dddd"[..]]);
        s.publish(&map).unwrap();
        s.publish(&map).unwrap();
        assert_eq!(s.release(&set, 0).unwrap(), 0, "one ref left");
        assert!(set.iter().all(|fp| s.contains(fp)));
        assert_eq!(s.release(&set, 0).unwrap(), 2, "last ref frees");
        assert!(set.iter().all(|fp| !s.contains(fp)));
        assert_eq!(s.release(&set, 0).unwrap(), 0, "idempotent on unknown fps");
    }

    #[test]
    fn crash_after_intent_replays_exactly_once() {
        let s = store();
        let (map, set) = fps_of(&[&b"eeee"[..], &b"ffff"[..]]);
        s.publish(&map).unwrap();
        // Arm a one-shot crash in the post-intent window.
        let fired = Arc::new(AtomicBool::new(false));
        let f2 = Arc::clone(&fired);
        s.set_fault_hook(Some(Arc::new(move |point: &str, _rank| {
            point == FAULT_GC_INTENT && !f2.swap(true, Ordering::SeqCst)
        })));
        assert_eq!(s.release(&set, 3).unwrap(), 0, "writer died post-intent");
        assert!(fired.load(Ordering::SeqCst));
        // Chunks still present, refcounts undisturbed on disk.
        assert!(set.iter().all(|fp| s.contains(fp)));
        // Replay applies the pending decrefs exactly once.
        assert!(s.replay_intent().unwrap());
        assert!(set.iter().all(|fp| !s.contains(fp)));
        assert!(!s.replay_intent().unwrap(), "no double replay");
        assert!(set.iter().all(|fp| s.refcount(fp) == 0));
    }

    #[test]
    fn next_release_replays_pending_intent_first() {
        let s = store();
        let (map_a, set_a) = fps_of(&[&b"g1g1"[..]]);
        let (map_b, set_b) = fps_of(&[&b"h2h2"[..]]);
        s.publish(&map_a).unwrap();
        s.publish(&map_b).unwrap();
        let fired = Arc::new(AtomicBool::new(false));
        let f2 = Arc::clone(&fired);
        s.set_fault_hook(Some(Arc::new(move |point: &str, _rank| {
            point == FAULT_GC_INTENT && !f2.swap(true, Ordering::SeqCst)
        })));
        s.release(&set_a, 0).unwrap(); // dies post-intent
        assert!(set_a.iter().all(|fp| s.contains(fp)));
        // A later GC (another writer on the node) replays, then proceeds.
        assert_eq!(s.release(&set_b, 1).unwrap(), 1);
        assert!(set_a.iter().all(|fp| !s.contains(fp)), "intent replayed");
        assert!(set_b.iter().all(|fp| !s.contains(fp)));
    }

    /// Regression: a publish landing between a crashed release and its
    /// replay persists the ledger — that snapshot must not mask the
    /// pending intent (the ledger carries the *applied* seq, not the
    /// provisional one the crashed release took).
    #[test]
    fn publish_between_crash_and_replay_does_not_mask_the_intent() {
        let s = store();
        let (map_a, set_a) = fps_of(&[&b"k3k3"[..]]);
        let (map_b, set_b) = fps_of(&[&b"m4m4"[..]]);
        s.publish(&map_a).unwrap();
        let fired = Arc::new(AtomicBool::new(false));
        let f2 = Arc::clone(&fired);
        s.set_fault_hook(Some(Arc::new(move |point: &str, _rank| {
            point == FAULT_GC_INTENT && !f2.swap(true, Ordering::SeqCst)
        })));
        s.release(&set_a, 0).unwrap(); // dies post-intent
        s.publish(&map_b).unwrap(); // another writer keeps working
        assert!(s.replay_intent().unwrap(), "intent must still be pending");
        assert!(set_a.iter().all(|fp| !s.contains(fp)), "decrefs applied");
        assert!(
            set_b.iter().all(|fp| s.contains(fp) && s.refcount(fp) == 1),
            "the interleaved publish must survive the replay"
        );
    }

    /// A torn intent object must be quarantined, not allowed to error out
    /// of every future release on the node.
    #[test]
    fn corrupt_intent_is_quarantined_not_wedging_gc() {
        let s = store();
        let (map, set) = fps_of(&[&b"p6p6"[..]]);
        s.publish(&map).unwrap();
        s.tier.put(&s.intent_key(), b"{not json").unwrap();
        assert!(!s.replay_intent().unwrap());
        assert!(!s.tier.exists(&s.intent_key()), "corrupt intent cleared");
        assert_eq!(s.release(&set, 0).unwrap(), 1, "GC must still work");
    }

    #[test]
    fn reset_forgets_counts_so_publish_rewrites_after_wipe() {
        let s = store();
        let (map, set) = fps_of(&[&b"n5n5"[..]]);
        s.publish(&map).unwrap();
        // Node failure: tier wiped out from under the store.
        s.tier.wipe();
        assert!(set.iter().all(|fp| !s.contains(fp)));
        s.reset();
        let stat = s.publish(&map).unwrap();
        assert_eq!(stat.novel_chunks, 1, "payload must be re-written");
        assert!(set.iter().all(|fp| s.contains(fp)));
    }

    #[test]
    fn get_rejects_corrupt_payload() {
        let s = store();
        let (map, set) = fps_of(&[&b"iiii"[..]]);
        s.publish(&map).unwrap();
        let fp = set.iter().next().unwrap();
        // Overwrite the stored object with different bytes.
        s.tier
            .put(&ChunkStore::chunk_key(fp), b"JJJJ")
            .unwrap();
        assert!(s.get(fp).is_none(), "fingerprint mismatch must miss");
    }
}
