//! Cluster topology: rank <-> node mapping, partner selection, erasure
//! groups.
//!
//! Replaces the MPI process grid of the original system (DESIGN.md
//! substitution table): ranks are in-process workers, but partner/group
//! construction follows the same rules multi-level checkpointing libraries
//! (SCR, VeloC) use — partners and erasure-group members must live in
//! *different failure domains* (nodes) or the redundancy is worthless.

/// Static description of the simulated cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub nodes: usize,
    pub ranks_per_node: usize,
}

impl Topology {
    pub fn new(nodes: usize, ranks_per_node: usize) -> Self {
        assert!(nodes > 0 && ranks_per_node > 0);
        Topology {
            nodes,
            ranks_per_node,
        }
    }

    pub fn world_size(&self) -> usize {
        self.nodes * self.ranks_per_node
    }

    /// Node hosting a rank (block distribution, like `mpirun --map-by node`
    /// with consecutive slots).
    pub fn node_of(&self, rank: usize) -> usize {
        assert!(rank < self.world_size());
        rank / self.ranks_per_node
    }

    pub fn ranks_of_node(&self, node: usize) -> std::ops::Range<usize> {
        assert!(node < self.nodes);
        node * self.ranks_per_node..(node + 1) * self.ranks_per_node
    }

    /// Partner for replication: same slot on the next node (ring over
    /// nodes), guaranteeing a distinct failure domain whenever nodes > 1.
    pub fn partner_of(&self, rank: usize) -> usize {
        let node = self.node_of(rank);
        let slot = rank % self.ranks_per_node;
        let pnode = (node + 1) % self.nodes;
        pnode * self.ranks_per_node + slot
    }

    /// Inverse of [`partner_of`]: whose partner am I?
    pub fn partner_source(&self, rank: usize) -> usize {
        let node = self.node_of(rank);
        let slot = rank % self.ranks_per_node;
        let pnode = (node + self.nodes - 1) % self.nodes;
        pnode * self.ranks_per_node + slot
    }

    /// Erasure group of `rank` for group size `g`: members are node-strided
    /// (same slot, nodes i, i+s, i+2s, ...), so one node failure costs at
    /// most one member per group — the single-erasure XOR code can always
    /// rebuild. Requires `nodes % g == 0`.
    pub fn erasure_group(&self, rank: usize, g: usize) -> Vec<usize> {
        assert!(g >= 2, "erasure group needs >= 2 members");
        assert!(
            self.nodes % g == 0,
            "nodes ({}) must be a multiple of group size ({g})",
            self.nodes
        );
        let slot = rank % self.ranks_per_node;
        let node = self.node_of(rank);
        let span = self.nodes / g; // node stride between members
        let base = node % span;
        (0..g)
            .map(|j| (base + j * span) * self.ranks_per_node + slot)
            .collect()
    }

    /// Index of `rank` within its erasure group.
    pub fn erasure_index(&self, rank: usize, g: usize) -> usize {
        self.erasure_group(rank, g)
            .iter()
            .position(|&r| r == rank)
            .expect("rank must be in its own group")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_mapping_block() {
        let t = Topology::new(4, 2);
        assert_eq!(t.world_size(), 8);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(1), 0);
        assert_eq!(t.node_of(2), 1);
        assert_eq!(t.ranks_of_node(1), 2..4);
    }

    #[test]
    fn partner_is_on_different_node() {
        let t = Topology::new(4, 2);
        for r in 0..t.world_size() {
            let p = t.partner_of(r);
            assert_ne!(t.node_of(r), t.node_of(p), "rank {r}");
            assert_eq!(t.partner_source(p), r);
        }
    }

    #[test]
    fn partner_ring_wraps() {
        let t = Topology::new(3, 1);
        assert_eq!(t.partner_of(2), 0);
        assert_eq!(t.partner_source(0), 2);
    }

    #[test]
    fn erasure_groups_node_disjoint() {
        let t = Topology::new(8, 2);
        for r in 0..t.world_size() {
            let grp = t.erasure_group(r, 4);
            assert_eq!(grp.len(), 4);
            assert!(grp.contains(&r));
            let nodes: std::collections::BTreeSet<_> =
                grp.iter().map(|&m| t.node_of(m)).collect();
            assert_eq!(nodes.len(), 4, "group of {r} spans distinct nodes");
        }
    }

    #[test]
    fn erasure_groups_consistent_across_members() {
        let t = Topology::new(8, 1);
        let g0 = t.erasure_group(0, 4);
        for &m in &g0 {
            assert_eq!(t.erasure_group(m, 4), g0);
        }
        assert_eq!(t.erasure_index(g0[2], 4), 2);
    }

    #[test]
    #[should_panic]
    fn erasure_group_requires_divisibility() {
        Topology::new(6, 1).erasure_group(0, 4);
    }
}
