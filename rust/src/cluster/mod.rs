//! Simulated multi-node cluster: topology, rank communication, failures.

pub mod comm;
pub mod failure;
pub mod topology;

pub use comm::{CommWorld, Endpoint, Message};
pub use failure::{FailureEvent, FailureInjector, FailureScope, KillSwitch, SeverityMix};
pub use topology::Topology;
