//! Failure injection: Poisson failure process with severity levels.
//!
//! Multi-level checkpointing exists because failures are *not* uniform:
//! most take out a single process or node (survivable from node-local or
//! partner copies), few take out several nodes (erasure rebuild), and only
//! rare catastrophes need the PFS copy. The default severity mix follows
//! the failure studies the VeloC/SCR line of work cites (~80/10/7/3).

use crate::cluster::topology::Topology;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// What a failure takes out.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FailureScope {
    /// One process dies; node-local storage of its node survives.
    Rank(usize),
    /// A whole node (all its ranks + node-local tiers).
    Node(usize),
    /// Several nodes at once (e.g. a rack / PDU event).
    MultiNode(Vec<usize>),
    /// Full-system outage; only persistent storage survives.
    System,
}

impl FailureScope {
    /// Minimum resilience level able to recover this failure:
    /// 1 = local, 2 = partner, 3 = erasure, 4 = PFS. (A node failure is
    /// recoverable from a partner on another node; a multi-node event may
    /// take a partner pair together, needing erasure or PFS.)
    pub fn min_level(&self) -> u8 {
        match self {
            FailureScope::Rank(_) => 1,
            FailureScope::Node(_) => 2,
            FailureScope::MultiNode(_) => 3,
            FailureScope::System => 4,
        }
    }
}

/// One scheduled failure at virtual time `at` seconds.
#[derive(Clone, Debug)]
pub struct FailureEvent {
    pub at: f64,
    pub scope: FailureScope,
}

/// Severity mix (probabilities sum to 1).
#[derive(Clone, Copy, Debug)]
pub struct SeverityMix {
    pub rank: f64,
    pub node: f64,
    pub multi_node: f64,
    pub system: f64,
}

impl Default for SeverityMix {
    fn default() -> Self {
        SeverityMix {
            rank: 0.80,
            node: 0.10,
            multi_node: 0.07,
            system: 0.03,
        }
    }
}

/// Poisson failure process over a topology.
#[derive(Clone, Debug)]
pub struct FailureInjector {
    pub topology: Topology,
    /// System-wide mean time between failures, seconds.
    pub mtbf: f64,
    pub mix: SeverityMix,
}

impl FailureInjector {
    pub fn new(topology: Topology, mtbf: f64) -> Self {
        FailureInjector {
            topology,
            mtbf,
            mix: SeverityMix::default(),
        }
    }

    pub fn with_mix(mut self, mix: SeverityMix) -> Self {
        self.mix = mix;
        self
    }

    fn sample_scope(&self, rng: &mut Rng) -> FailureScope {
        let x = rng.f64();
        let m = &self.mix;
        if x < m.rank {
            FailureScope::Rank(rng.range_usize(0, self.topology.world_size()))
        } else if x < m.rank + m.node {
            FailureScope::Node(rng.range_usize(0, self.topology.nodes))
        } else if x < m.rank + m.node + m.multi_node {
            // A node and its ring-neighbour: exactly the pattern that kills
            // a partner pair and forces erasure/PFS recovery.
            let n = rng.range_usize(0, self.topology.nodes);
            let m2 = (n + 1) % self.topology.nodes;
            if m2 == n {
                FailureScope::Node(n)
            } else {
                FailureScope::MultiNode(vec![n, m2])
            }
        } else {
            FailureScope::System
        }
    }

    /// Draw the failure schedule for `horizon` seconds of execution.
    pub fn schedule(&self, rng: &mut Rng, horizon: f64) -> Vec<FailureEvent> {
        let mut out = Vec::new();
        let mut t = 0.0;
        loop {
            t += rng.exponential(1.0 / self.mtbf);
            if t >= horizon {
                break;
            }
            out.push(FailureEvent {
                at: t,
                scope: self.sample_scope(rng),
            });
        }
        out
    }

    /// Ranks killed by a scope.
    pub fn affected_ranks(&self, scope: &FailureScope) -> Vec<usize> {
        match scope {
            FailureScope::Rank(r) => vec![*r],
            FailureScope::Node(n) => self.topology.ranks_of_node(*n).collect(),
            FailureScope::MultiNode(ns) => ns
                .iter()
                .flat_map(|&n| self.topology.ranks_of_node(n))
                .collect(),
            FailureScope::System => (0..self.topology.world_size()).collect(),
        }
    }

    /// Nodes whose local storage is wiped by a scope.
    pub fn affected_nodes(&self, scope: &FailureScope) -> Vec<usize> {
        match scope {
            // A rank crash does NOT wipe node storage — that is exactly why
            // level-1 (node-local) recovery works for it.
            FailureScope::Rank(_) => vec![],
            FailureScope::Node(n) => vec![*n],
            FailureScope::MultiNode(ns) => ns.clone(),
            FailureScope::System => (0..self.topology.nodes).collect(),
        }
    }
}

/// Per-rank kill switches checked by running rank loops.
#[derive(Clone)]
pub struct KillSwitch {
    flags: Arc<Vec<AtomicBool>>,
}

impl KillSwitch {
    pub fn new(world_size: usize) -> Self {
        KillSwitch {
            flags: Arc::new((0..world_size).map(|_| AtomicBool::new(false)).collect()),
        }
    }

    pub fn kill(&self, rank: usize) {
        self.flags[rank].store(true, Ordering::SeqCst);
    }

    pub fn is_killed(&self, rank: usize) -> bool {
        self.flags[rank].load(Ordering::SeqCst)
    }

    pub fn revive(&self, rank: usize) {
        self.flags[rank].store(false, Ordering::SeqCst);
    }

    pub fn any_killed(&self) -> bool {
        self.flags.iter().any(|f| f.load(Ordering::SeqCst))
    }

    pub fn killed_ranks(&self) -> Vec<usize> {
        self.flags
            .iter()
            .enumerate()
            .filter(|(_, f)| f.load(Ordering::SeqCst))
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inj() -> FailureInjector {
        FailureInjector::new(Topology::new(8, 2), 100.0)
    }

    #[test]
    fn schedule_rate_matches_mtbf() {
        let mut rng = Rng::new(1);
        let events = inj().schedule(&mut rng, 100_000.0);
        // Expect ~1000 events at MTBF 100s over 100k s.
        assert!(
            (events.len() as f64 - 1000.0).abs() < 150.0,
            "{} events",
            events.len()
        );
        assert!(events.windows(2).all(|w| w[0].at <= w[1].at));
    }

    #[test]
    fn severity_mix_roughly_respected() {
        let mut rng = Rng::new(2);
        let events = inj().schedule(&mut rng, 1_000_000.0);
        let total = events.len() as f64;
        let ranks = events
            .iter()
            .filter(|e| matches!(e.scope, FailureScope::Rank(_)))
            .count() as f64;
        assert!((ranks / total - 0.80).abs() < 0.05, "{}", ranks / total);
    }

    #[test]
    fn min_levels_ordered_by_severity() {
        assert_eq!(FailureScope::Rank(0).min_level(), 1);
        assert_eq!(FailureScope::Node(0).min_level(), 2);
        assert_eq!(FailureScope::MultiNode(vec![0, 1]).min_level(), 3);
        assert_eq!(FailureScope::System.min_level(), 4);
    }

    #[test]
    fn affected_sets() {
        let i = inj();
        assert_eq!(i.affected_ranks(&FailureScope::Node(1)), vec![2, 3]);
        assert!(i.affected_nodes(&FailureScope::Rank(5)).is_empty());
        assert_eq!(
            i.affected_nodes(&FailureScope::MultiNode(vec![0, 1])),
            vec![0, 1]
        );
        assert_eq!(i.affected_ranks(&FailureScope::System).len(), 16);
    }

    #[test]
    fn kill_switch_lifecycle() {
        let ks = KillSwitch::new(4);
        assert!(!ks.any_killed());
        ks.kill(2);
        assert!(ks.is_killed(2));
        assert_eq!(ks.killed_ranks(), vec![2]);
        ks.revive(2);
        assert!(!ks.any_killed());
    }

    #[test]
    fn multinode_kills_partner_pair() {
        // Adjacent nodes are exactly partner pairs under the ring mapping;
        // verify the generated multi-node scope has that shape.
        let i = inj();
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            if let FailureScope::MultiNode(ns) = i.sample_scope(&mut rng) {
                assert_eq!(ns.len(), 2);
                assert_eq!(ns[1], (ns[0] + 1) % i.topology.nodes);
                return;
            }
        }
        panic!("no multi-node event sampled");
    }
}
