//! In-process message passing between ranks — the MPI substitute.
//!
//! Each rank owns a tagged mailbox; `send` is non-blocking, `recv` blocks
//! with a timeout (so a failed partner surfaces as `Timeout` instead of a
//! hang, which is how the resilience modules detect a dead peer mid-
//! protocol). Collectives (barrier, gather, bcast, allreduce) are built on
//! the point-to-point layer exactly like a textbook MPI implementation.

use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A tagged message.
#[derive(Clone, Debug, PartialEq)]
pub struct Message {
    pub from: usize,
    pub tag: u32,
    pub data: Vec<u8>,
}

struct Mailbox {
    queue: Mutex<VecDeque<Message>>,
    cv: Condvar,
}

struct BarrierState {
    count: usize,
    generation: u64,
}

struct WorldInner {
    mailboxes: Vec<Mailbox>,
    barrier: Mutex<BarrierState>,
    barrier_cv: Condvar,
}

/// Shared communicator for `n` ranks.
#[derive(Clone)]
pub struct CommWorld {
    inner: Arc<WorldInner>,
}

impl CommWorld {
    pub fn new(world_size: usize) -> Self {
        let mailboxes = (0..world_size)
            .map(|_| Mailbox {
                queue: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
            })
            .collect();
        CommWorld {
            inner: Arc::new(WorldInner {
                mailboxes,
                barrier: Mutex::new(BarrierState {
                    count: 0,
                    generation: 0,
                }),
                barrier_cv: Condvar::new(),
            }),
        }
    }

    pub fn world_size(&self) -> usize {
        self.inner.mailboxes.len()
    }

    /// Per-rank endpoint handle.
    pub fn endpoint(&self, rank: usize) -> Endpoint {
        assert!(rank < self.world_size());
        Endpoint {
            world: self.clone(),
            rank,
        }
    }
}

/// A rank's view of the communicator.
#[derive(Clone)]
pub struct Endpoint {
    world: CommWorld,
    rank: usize,
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world_size(&self) -> usize {
        self.world.world_size()
    }

    /// Non-blocking send.
    pub fn send(&self, to: usize, tag: u32, data: Vec<u8>) {
        let mb = &self.world.inner.mailboxes[to];
        mb.queue.lock().unwrap().push_back(Message {
            from: self.rank,
            tag,
            data,
        });
        mb.cv.notify_all();
    }

    /// Blocking receive of the first message matching `tag` (and `from`, if
    /// given), leaving non-matching messages queued.
    pub fn recv(
        &self,
        from: Option<usize>,
        tag: u32,
        timeout: Duration,
    ) -> Result<Message> {
        let mb = &self.world.inner.mailboxes[self.rank];
        let deadline = Instant::now() + timeout;
        let mut q = mb.queue.lock().unwrap();
        loop {
            if let Some(pos) = q
                .iter()
                .position(|m| m.tag == tag && from.map_or(true, |f| m.from == f))
            {
                return Ok(q.remove(pos).unwrap());
            }
            let now = Instant::now();
            if now >= deadline {
                bail!(
                    "recv timeout: rank {} waiting for tag {tag} from {:?}",
                    self.rank,
                    from
                );
            }
            let (guard, _t) = mb.cv.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }

    /// Generation-counted reusable barrier over all ranks.
    pub fn barrier(&self, timeout: Duration) -> Result<()> {
        let inner = &self.world.inner;
        let mut st = inner.barrier.lock().unwrap();
        let gen = st.generation;
        st.count += 1;
        if st.count == self.world.world_size() {
            st.count = 0;
            st.generation += 1;
            inner.barrier_cv.notify_all();
            return Ok(());
        }
        let deadline = Instant::now() + timeout;
        while st.generation == gen {
            let now = Instant::now();
            if now >= deadline {
                // Withdraw our contribution so a later retry is consistent.
                st.count = st.count.saturating_sub(1);
                bail!("barrier timeout at rank {}", self.rank);
            }
            let (guard, _t) = inner
                .barrier_cv
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
        }
        Ok(())
    }

    /// Gather byte payloads at `root`; returns `Some(vec_by_rank)` at root.
    pub fn gather(
        &self,
        root: usize,
        tag: u32,
        data: Vec<u8>,
        timeout: Duration,
    ) -> Result<Option<Vec<Vec<u8>>>> {
        if self.rank == root {
            let mut out: Vec<Vec<u8>> = vec![Vec::new(); self.world_size()];
            out[root] = data;
            for _ in 0..self.world_size() - 1 {
                let m = self.recv(None, tag, timeout)?;
                out[m.from] = m.data;
            }
            Ok(Some(out))
        } else {
            self.send(root, tag, data);
            Ok(None)
        }
    }

    /// Broadcast from `root` to everyone; returns the payload.
    pub fn bcast(
        &self,
        root: usize,
        tag: u32,
        data: Option<Vec<u8>>,
        timeout: Duration,
    ) -> Result<Vec<u8>> {
        if self.rank == root {
            let data = data.expect("root must supply bcast payload");
            for r in 0..self.world_size() {
                if r != root {
                    self.send(r, tag, data.clone());
                }
            }
            Ok(data)
        } else {
            Ok(self.recv(Some(root), tag, timeout)?.data)
        }
    }

    /// All-reduce a u64 with `op` (via gather at rank 0 + bcast).
    pub fn allreduce_u64(
        &self,
        tag: u32,
        value: u64,
        op: fn(u64, u64) -> u64,
        timeout: Duration,
    ) -> Result<u64> {
        let gathered =
            self.gather(0, tag, value.to_le_bytes().to_vec(), timeout)?;
        let reduced = if let Some(all) = gathered {
            let mut acc: Option<u64> = None;
            for bytes in all {
                let v = u64::from_le_bytes(bytes[..8].try_into().unwrap());
                acc = Some(match acc {
                    None => v,
                    Some(a) => op(a, v),
                });
            }
            Some(acc.unwrap().to_le_bytes().to_vec())
        } else {
            None
        };
        let out = self.bcast(0, tag.wrapping_add(1), reduced, timeout)?;
        Ok(u64::from_le_bytes(out[..8].try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    const T: Duration = Duration::from_secs(5);

    #[test]
    fn send_recv_tag_matching() {
        let world = CommWorld::new(2);
        let a = world.endpoint(0);
        let b = world.endpoint(1);
        a.send(1, 7, vec![1]);
        a.send(1, 9, vec![2]);
        // Receive tag 9 first even though tag 7 arrived earlier.
        assert_eq!(b.recv(None, 9, T).unwrap().data, vec![2]);
        assert_eq!(b.recv(Some(0), 7, T).unwrap().data, vec![1]);
    }

    #[test]
    fn recv_timeout_errors() {
        let world = CommWorld::new(1);
        let e = world.endpoint(0);
        let err = e.recv(None, 1, Duration::from_millis(20));
        assert!(err.is_err());
    }

    #[test]
    fn barrier_synchronizes() {
        let world = CommWorld::new(4);
        let handles: Vec<_> = (0..4)
            .map(|r| {
                let ep = world.endpoint(r);
                thread::spawn(move || {
                    for _ in 0..10 {
                        ep.barrier(T).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn barrier_times_out_when_rank_missing() {
        let world = CommWorld::new(2);
        let e = world.endpoint(0);
        assert!(e.barrier(Duration::from_millis(30)).is_err());
    }

    #[test]
    fn gather_and_bcast() {
        let world = CommWorld::new(3);
        let handles: Vec<_> = (0..3)
            .map(|r| {
                let ep = world.endpoint(r);
                thread::spawn(move || {
                    let g = ep.gather(0, 5, vec![r as u8], T).unwrap();
                    if r == 0 {
                        assert_eq!(
                            g.unwrap(),
                            vec![vec![0u8], vec![1u8], vec![2u8]]
                        );
                    }
                    let payload = if r == 0 { Some(vec![42u8]) } else { None };
                    let b = ep.bcast(0, 6, payload, T).unwrap();
                    assert_eq!(b, vec![42u8]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn allreduce_max() {
        let world = CommWorld::new(4);
        let handles: Vec<_> = (0..4)
            .map(|r| {
                let ep = world.endpoint(r);
                thread::spawn(move || {
                    let m = ep
                        .allreduce_u64(11, (r * 10) as u64, u64::max, T)
                        .unwrap();
                    assert_eq!(m, 30);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
