//! Shared refcounted buffer pool — the zero-copy data plane substrate.
//!
//! Two pieces:
//!
//! - [`Bytes`]: an immutable, refcounted byte slice (backing allocation +
//!   offset + length). Cloning and sub-slicing are O(1) pointer bumps, so
//!   the pipeline, the erasure sharding, the aggregation segments and the
//!   daemon IPC boundary can all reference one capture allocation instead
//!   of `to_vec()`ing it per stage. A `Bytes` can wrap a plain `Vec`, an
//!   existing `Arc<Vec<u8>>` (no copy), or a pooled block that returns to
//!   its [`BufPool`] when the last reference drops.
//! - [`BufPool`]: a size-classed free list of capture buffers. The capture
//!   path encodes every checkpoint into a pooled block, so steady-state
//!   checkpointing stops allocating fresh multi-megabyte buffers per
//!   version (§Perf: the allocator round-trip and page-fault warmup were
//!   visible next to the kernels once the memcpys were gone).
//!
//! ## Copy accounting
//!
//! The module also hosts the *payload copy counter*: a process-global
//! count of payload memcpys performed at instrumented sites (Bytes owned
//! extraction, memory-tier `put`/`get` copy paths). The zero-copy test
//! asserts the counter stays flat across a full capture → level-1..4
//! pipeline. Derived-data construction (parity, delta containers, zlib
//! output) and real file I/O are *not* counted — they are new bytes or
//! device transfers, not redundant copies of an existing payload.

use std::collections::HashMap;
use std::ops::{Bound, Deref, RangeBounds};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

static PAYLOAD_COPIES: AtomicU64 = AtomicU64::new(0);
static PAYLOAD_COPY_BYTES: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static TL_PAYLOAD_COPIES: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Number of payload memcpys observed at instrumented sites so far,
/// process-wide. The zero-copy gate test (single test in its own binary,
/// so nothing else pumps the counter) asserts this stays flat across a
/// full capture → level-1..4 pipeline run.
pub fn payload_copies() -> u64 {
    PAYLOAD_COPIES.load(Ordering::SeqCst)
}

/// Total bytes moved by those copies, process-wide.
pub fn payload_copy_bytes() -> u64 {
    PAYLOAD_COPY_BYTES.load(Ordering::SeqCst)
}

/// Payload memcpys performed *by the calling thread*. Unit tests assert
/// on this one — it cannot be polluted by concurrently running tests.
pub fn thread_payload_copies() -> u64 {
    TL_PAYLOAD_COPIES.with(|c| c.get())
}

/// Record one payload memcpy of `bytes` bytes (instrumentation sites only).
pub fn count_payload_copy(bytes: usize) {
    PAYLOAD_COPIES.fetch_add(1, Ordering::SeqCst);
    PAYLOAD_COPY_BYTES.fetch_add(bytes as u64, Ordering::SeqCst);
    TL_PAYLOAD_COPIES.with(|c| c.set(c.get() + 1));
}

/// One backing allocation a [`Bytes`] can reference.
enum Backing {
    /// A plain owned vector (or a pooled block, when `pool` is set: the
    /// block returns to its free list when the last `Bytes` drops).
    Block {
        buf: Vec<u8>,
        pool: Option<Arc<PoolShared>>,
    },
    /// An existing shared vector, wrapped without copying.
    Shared(Arc<Vec<u8>>),
}

impl Backing {
    fn as_slice(&self) -> &[u8] {
        match self {
            Backing::Block { buf, .. } => buf.as_slice(),
            Backing::Shared(a) => a.as_slice(),
        }
    }
}

impl Drop for Backing {
    fn drop(&mut self) {
        if let Backing::Block { buf, pool: Some(p) } = self {
            p.recycle(std::mem::take(buf));
        }
    }
}

/// Immutable refcounted byte slice: backing + offset + length. Clone and
/// [`Bytes::slice`] are O(1); the bytes themselves are never copied unless
/// an owned extraction ([`Bytes::to_vec`] / [`Bytes::to_arc_vec`]) asks
/// for one — and those are copy-counted.
#[derive(Clone)]
pub struct Bytes {
    backing: Arc<Backing>,
    off: usize,
    len: usize,
}

impl Bytes {
    /// The empty slice (no allocation).
    pub fn new() -> Bytes {
        Bytes::from(Vec::new())
    }

    /// Wrap an existing shared vector without copying it.
    pub fn from_arc(data: Arc<Vec<u8>>) -> Bytes {
        let len = data.len();
        Bytes {
            backing: Arc::new(Backing::Shared(data)),
            off: 0,
            len,
        }
    }

    /// Owned copy of a borrowed slice. This is a real payload memcpy and
    /// counts as one — callers that can avoid it should hold a `Bytes`.
    pub fn copy_from_slice(data: &[u8]) -> Bytes {
        count_payload_copy(data.len());
        Bytes::from(data.to_vec())
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// O(1) sub-slice sharing the same backing allocation (keeps the whole
    /// backing alive, like any refcounted slice). Panics when the range
    /// exceeds the slice, matching `&data[range]`.
    pub fn slice(&self, range: impl RangeBounds<usize>) -> Bytes {
        let start = match range.start_bound() {
            Bound::Included(&s) => s,
            Bound::Excluded(&s) => s + 1,
            Bound::Unbounded => 0,
        };
        let end = match range.end_bound() {
            Bound::Included(&e) => e + 1,
            Bound::Excluded(&e) => e,
            Bound::Unbounded => self.len,
        };
        assert!(
            start <= end && end <= self.len,
            "Bytes::slice range {start}..{end} out of bounds (len {})",
            self.len
        );
        Bytes {
            backing: Arc::clone(&self.backing),
            off: self.off + start,
            len: end - start,
        }
    }

    /// Owned `Vec` copy of the slice (copy-counted).
    pub fn to_vec(&self) -> Vec<u8> {
        count_payload_copy(self.len);
        self.as_slice().to_vec()
    }

    /// Shared-vector view: free when the backing *is* a whole shared
    /// vector already, otherwise an owned (copy-counted) extraction.
    pub fn to_arc_vec(&self) -> Arc<Vec<u8>> {
        if let Backing::Shared(a) = &*self.backing {
            if self.off == 0 && self.len == a.len() {
                return Arc::clone(a);
            }
        }
        count_payload_copy(self.len);
        Arc::new(self.as_slice().to_vec())
    }

    fn as_slice(&self) -> &[u8] {
        &self.backing.as_slice()[self.off..self.off + self.len]
    }
}

impl Default for Bytes {
    fn default() -> Self {
        Bytes::new()
    }
}

impl Deref for Bytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for Bytes {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl From<Vec<u8>> for Bytes {
    /// Take ownership of a vector without copying it.
    fn from(buf: Vec<u8>) -> Bytes {
        let len = buf.len();
        Bytes {
            backing: Arc::new(Backing::Block { buf, pool: None }),
            off: 0,
            len,
        }
    }
}

impl From<Arc<Vec<u8>>> for Bytes {
    fn from(data: Arc<Vec<u8>>) -> Bytes {
        Bytes::from_arc(data)
    }
}

impl std::fmt::Debug for Bytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Bytes({} bytes @ off {})", self.len, self.off)
    }
}

impl PartialEq for Bytes {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Bytes {}

/// Per-class free-list cap: unbounded retention would pin one peak's worth
/// of buffers forever; a small cap keeps the steady-state hit rate without
/// the memory tail.
const MAX_PER_CLASS: usize = 8;
/// Blocks above this capacity are dropped instead of pooled.
const MAX_POOLED: usize = 256 << 20;

struct PoolShared {
    /// capacity-class (power of two) -> recycled blocks.
    classes: Mutex<HashMap<usize, Vec<Vec<u8>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    recycled: AtomicU64,
}

impl PoolShared {
    fn recycle(&self, mut buf: Vec<u8>) {
        let class = buf.capacity().next_power_of_two();
        if buf.capacity() == 0 || class > MAX_POOLED {
            return;
        }
        buf.clear();
        let mut classes = self.classes.lock().unwrap();
        let list = classes.entry(class).or_default();
        if list.len() < MAX_PER_CLASS {
            list.push(buf);
            self.recycled.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Counters exposed for tests and diagnostics.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// `take` calls served from a free list.
    pub hits: u64,
    /// `take` calls that had to allocate.
    pub misses: u64,
    /// Blocks returned to a free list so far.
    pub recycled: u64,
}

/// Size-classed buffer pool (see the [module docs](self)).
pub struct BufPool {
    shared: Arc<PoolShared>,
}

impl BufPool {
    pub fn new() -> BufPool {
        BufPool {
            shared: Arc::new(PoolShared {
                classes: Mutex::new(HashMap::new()),
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                recycled: AtomicU64::new(0),
            }),
        }
    }

    /// Check out a writable block with at least `capacity_hint` capacity.
    /// Freeze it into a [`Bytes`] when done; the block returns to this
    /// pool when the last reference drops.
    pub fn take(&self, capacity_hint: usize) -> PooledBuf {
        let class = capacity_hint.max(1).next_power_of_two();
        let reuse = {
            let mut classes = self.shared.classes.lock().unwrap();
            // Exact class first, then the next one up (a slightly larger
            // block serves a smaller request fine).
            let mut hit = classes.get_mut(&class).and_then(|l| l.pop());
            if hit.is_none() {
                hit = classes.get_mut(&(class * 2)).and_then(|l| l.pop());
            }
            hit
        };
        let buf = match reuse {
            Some(b) => {
                self.shared.hits.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                self.shared.misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(class)
            }
        };
        PooledBuf {
            buf,
            pool: Arc::clone(&self.shared),
        }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.shared.hits.load(Ordering::Relaxed),
            misses: self.shared.misses.load(Ordering::Relaxed),
            recycled: self.shared.recycled.load(Ordering::Relaxed),
        }
    }
}

impl Default for BufPool {
    fn default() -> Self {
        BufPool::new()
    }
}

/// Process-wide pool used by the capture path.
pub fn global() -> &'static BufPool {
    static POOL: OnceLock<BufPool> = OnceLock::new();
    POOL.get_or_init(BufPool::new)
}

/// A checked-out writable block. Deref to `Vec<u8>` for encoding into,
/// then [`PooledBuf::freeze`] to publish it as immutable shared bytes.
pub struct PooledBuf {
    buf: Vec<u8>,
    pool: Arc<PoolShared>,
}

impl PooledBuf {
    /// Publish the written bytes as an immutable [`Bytes`]; the block
    /// returns to the pool when the last reference drops.
    pub fn freeze(self) -> Bytes {
        let len = self.buf.len();
        Bytes {
            backing: Arc::new(Backing::Block {
                buf: self.buf,
                pool: Some(self.pool),
            }),
            off: 0,
            len,
        }
    }
}

impl Deref for PooledBuf {
    type Target = Vec<u8>;

    fn deref(&self) -> &Vec<u8> {
        &self.buf
    }
}

impl std::ops::DerefMut for PooledBuf {
    fn deref_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clone_and_slice_share_backing_without_copies() {
        let before = thread_payload_copies();
        let b = Bytes::from(vec![1u8, 2, 3, 4, 5, 6, 7, 8]);
        let c = b.clone();
        let s = b.slice(2..6);
        assert_eq!(&*s, &[3, 4, 5, 6]);
        assert_eq!(&*c, &*b);
        let ss = s.slice(1..=2);
        assert_eq!(&*ss, &[4, 5]);
        assert_eq!(thread_payload_copies(), before, "no copy on clone/slice");
    }

    #[test]
    fn arc_wrap_and_unwrap_are_free() {
        let a = Arc::new(vec![9u8; 64]);
        let before = thread_payload_copies();
        let b = Bytes::from_arc(Arc::clone(&a));
        assert_eq!(b.len(), 64);
        let back = b.to_arc_vec();
        assert!(Arc::ptr_eq(&a, &back), "whole-slice Shared view is free");
        assert_eq!(thread_payload_copies(), before);
        // A sub-slice extraction must copy (and count).
        let sub = b.slice(1..3).to_arc_vec();
        assert_eq!(*sub, vec![9u8, 9]);
        assert_eq!(thread_payload_copies(), before + 1);
    }

    #[test]
    fn owned_extractions_are_counted() {
        let b = Bytes::from(vec![7u8; 100]);
        let c0 = thread_payload_copies();
        let v = b.to_vec();
        assert_eq!(v.len(), 100);
        assert_eq!(thread_payload_copies(), c0 + 1);
        let _ = Bytes::copy_from_slice(&v);
        assert_eq!(thread_payload_copies(), c0 + 2);
    }

    #[test]
    fn pool_recycles_frozen_blocks() {
        let pool = BufPool::new();
        let mut b = pool.take(1000);
        b.extend_from_slice(&[1u8; 1000]);
        let ptr = b.as_ptr() as usize;
        let frozen = b.freeze();
        assert_eq!(frozen.len(), 1000);
        drop(frozen); // last ref: block returns to the pool
        assert_eq!(pool.stats().recycled, 1);
        let b2 = pool.take(900);
        assert_eq!(pool.stats().hits, 1, "same class served from free list");
        assert_eq!(b2.as_ptr() as usize, ptr, "allocation actually reused");
        assert!(b2.is_empty(), "recycled block comes back cleared");
    }

    #[test]
    fn pool_survives_outstanding_refs() {
        let pool = BufPool::new();
        let mut b = pool.take(64);
        b.extend_from_slice(b"hello world");
        let frozen = b.freeze();
        let s = frozen.slice(6..);
        drop(frozen);
        // The sub-slice still holds the backing: not recycled yet.
        assert_eq!(pool.stats().recycled, 0);
        assert_eq!(&*s, b"world");
        drop(s);
        assert_eq!(pool.stats().recycled, 1);
    }

    #[test]
    fn empty_and_default() {
        let b = Bytes::new();
        assert!(b.is_empty());
        assert_eq!(b.slice(..).len(), 0);
        assert_eq!(Bytes::default(), b);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn slice_out_of_bounds_panics() {
        let b = Bytes::from(vec![1u8, 2, 3]);
        let _ = b.slice(1..5);
    }
}
