//! VCKP — VeloC checkpoint container format.
//!
//! Layout (little-endian):
//!
//! ```text
//! magic   "VCKP"            4 bytes
//! version u32               format version (1)
//! hlen    u32               header JSON length
//! header  JSON              {"id","rank","iteration","regions":[{"id","len"}]}
//! body    region payloads   concatenated in header order
//! crc     u32               CRC32 of everything above
//! ```
//!
//! The same encoding is written to every resilience level (local tier,
//! partner copy, PFS, KV store), so recovery can validate any copy with the
//! trailing CRC before the integrity module's checksum kernel re-verifies
//! region contents.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

pub const MAGIC: &[u8; 4] = b"VCKP";
pub const VERSION: u32 = 1;

/// Checkpoint metadata carried in the header.
#[derive(Clone, Debug, PartialEq)]
pub struct CkptMeta {
    /// Checkpoint name (application-chosen, e.g. "hacc").
    pub name: String,
    pub rank: usize,
    /// Monotonic checkpoint version number.
    pub iteration: u64,
}

/// One registered memory region's payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Region {
    pub id: u32,
    pub data: Vec<u8>,
}

/// In-memory checkpoint: metadata + region payloads.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub meta: CkptMeta,
    pub regions: Vec<Region>,
}

impl Checkpoint {
    pub fn new(name: &str, rank: usize, iteration: u64) -> Self {
        Checkpoint {
            meta: CkptMeta {
                name: name.to_string(),
                rank,
                iteration,
            },
            regions: Vec::new(),
        }
    }

    pub fn push_region(&mut self, id: u32, data: Vec<u8>) {
        self.regions.push(Region { id, data });
    }

    pub fn region(&self, id: u32) -> Option<&Region> {
        self.regions.iter().find(|r| r.id == id)
    }

    pub fn payload_bytes(&self) -> u64 {
        self.regions.iter().map(|r| r.data.len() as u64).sum()
    }

    /// Container size `encode` will produce (for pool capacity hints).
    pub fn encoded_size_hint(&self) -> usize {
        let body_len: usize = self.regions.iter().map(|r| r.data.len()).sum();
        // Magic + version + hlen + header estimate + body + CRC; the header
        // estimate only has to be close — the pool rounds up to a class.
        12 + 96 + self.regions.len() * 32 + self.meta.name.len() + body_len + 4
    }

    /// Serialize into the VCKP container.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_size_hint());
        self.encode_into(&mut out);
        out
    }

    /// Serialize into a caller-provided buffer (appends). This is how the
    /// capture path encodes directly into a pooled block instead of a
    /// fresh allocation per version.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let start = out.len();
        let regions: Vec<Json> = self
            .regions
            .iter()
            .map(|r| {
                Json::obj()
                    .set("id", r.id as u64)
                    .set("len", r.data.len() as u64)
            })
            .collect();
        let header = Json::obj()
            .set("name", self.meta.name.as_str())
            .set("rank", self.meta.rank)
            .set("iteration", self.meta.iteration)
            .set("regions", Json::Arr(regions))
            .to_string();
        let hbytes = header.as_bytes();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(hbytes.len() as u32).to_le_bytes());
        out.extend_from_slice(hbytes);
        for r in &self.regions {
            out.extend_from_slice(&r.data);
        }
        let crc = crc32fast::hash(&out[start..]);
        out.extend_from_slice(&crc.to_le_bytes());
    }

    /// Parse and CRC-validate a VCKP container.
    pub fn decode(buf: &[u8]) -> Result<Checkpoint> {
        if buf.len() < 16 {
            bail!("VCKP too short ({} bytes)", buf.len());
        }
        if &buf[0..4] != MAGIC {
            bail!("bad VCKP magic");
        }
        let stored_crc =
            u32::from_le_bytes(buf[buf.len() - 4..].try_into().unwrap());
        let actual_crc = crc32fast::hash(&buf[..buf.len() - 4]);
        if stored_crc != actual_crc {
            bail!(
                "VCKP CRC mismatch: stored {stored_crc:#010x}, actual {actual_crc:#010x}"
            );
        }
        let version = u32::from_le_bytes(buf[4..8].try_into().unwrap());
        if version != VERSION {
            bail!("unsupported VCKP version {version}");
        }
        let hlen =
            u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
        let hend = 12 + hlen;
        if buf.len() < hend + 4 {
            bail!("VCKP header truncated");
        }
        let header = std::str::from_utf8(&buf[12..hend])
            .map_err(|_| anyhow!("VCKP header not utf-8"))?;
        let j = Json::parse(header).map_err(|e| anyhow!("VCKP header: {e}"))?;
        let meta = CkptMeta {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("header missing name"))?
                .to_string(),
            rank: j
                .get("rank")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("header missing rank"))?,
            iteration: j
                .get("iteration")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("header missing iteration"))?,
        };
        let rspecs = j
            .get("regions")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("header missing regions"))?;
        let mut regions = Vec::with_capacity(rspecs.len());
        let mut off = hend;
        for rs in rspecs {
            let id = rs
                .get("id")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("region missing id"))? as u32;
            let len = rs
                .get("len")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("region missing len"))?;
            if off + len > buf.len() - 4 {
                bail!("region {id} overruns container");
            }
            regions.push(Region {
                id,
                data: buf[off..off + len].to_vec(),
            });
            off += len;
        }
        if off != buf.len() - 4 {
            bail!("trailing bytes in VCKP body");
        }
        Ok(Checkpoint { meta, regions })
    }
}

// ---------------------------------------------------------------------------
// Typed slice <-> byte helpers (DNN parameter regions are f32 tensors).
// ---------------------------------------------------------------------------

pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

pub fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        bail!("byte length {} not a multiple of 4", b.len());
    }
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

pub fn i32s_to_bytes(xs: &[i32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Reinterpret bytes as i32 lanes, zero-padding the tail to `align` lanes.
pub fn bytes_to_i32s_padded(b: &[u8], align: usize) -> Vec<i32> {
    let lanes = b.len().div_ceil(4);
    let padded = if align > 0 { lanes.div_ceil(align) * align } else { lanes };
    let mut out = vec![0i32; padded];
    for (i, c) in b.chunks(4).enumerate() {
        let mut word = [0u8; 4];
        word[..c.len()].copy_from_slice(c);
        out[i] = i32::from_le_bytes(word);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut c = Checkpoint::new("app", 3, 17);
        c.push_region(0, vec![1, 2, 3, 4, 5]);
        c.push_region(7, vec![9; 1000]);
        c.push_region(2, Vec::new()); // empty regions are legal
        c
    }

    #[test]
    fn roundtrip() {
        let c = sample();
        let buf = c.encode();
        let d = Checkpoint::decode(&buf).unwrap();
        assert_eq!(c, d);
        assert_eq!(d.meta.iteration, 17);
        assert_eq!(d.region(7).unwrap().data.len(), 1000);
    }

    #[test]
    fn crc_detects_corruption() {
        let mut buf = sample().encode();
        let mid = buf.len() / 2;
        buf[mid] ^= 0xFF;
        let err = Checkpoint::decode(&buf).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
    }

    #[test]
    fn rejects_truncation() {
        let buf = sample().encode();
        assert!(Checkpoint::decode(&buf[..buf.len() - 10]).is_err());
        assert!(Checkpoint::decode(&buf[..8]).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut buf = sample().encode();
        buf[0] = b'X';
        assert!(Checkpoint::decode(&buf).is_err());
    }

    #[test]
    fn f32_roundtrip() {
        let xs = vec![1.5f32, -2.25, 0.0, f32::MAX];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&xs)).unwrap(), xs);
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }

    #[test]
    fn i32_padding() {
        let b = vec![1u8, 0, 0, 0, 2]; // 1 full lane + 1 partial
        let lanes = bytes_to_i32s_padded(&b, 4);
        assert_eq!(lanes.len(), 4);
        assert_eq!(lanes[0], 1);
        assert_eq!(lanes[1], 2);
        assert_eq!(lanes[2], 0);
    }

    #[test]
    fn payload_bytes_sums_regions() {
        assert_eq!(sample().payload_bytes(), 1005);
    }
}
