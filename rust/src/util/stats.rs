//! Streaming statistics and measurement helpers (criterion is not available
//! offline; `benches/` builds its harness on top of this module).

use crate::util::rng::Rng;
use std::time::{Duration, Instant};

/// Welford online mean/variance plus min/max.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }
}

/// Retained observations are bounded at this count by default; past it
/// the reservoir keeps a uniform random subset (Vitter's algorithm R).
pub const SAMPLES_DEFAULT_CAP: usize = 4096;

/// Bounded sample reservoir with percentiles (sorts on query).
///
/// Below [`SAMPLES_DEFAULT_CAP`] observations every value is retained and
/// percentiles are exact. Past the cap, algorithm R replaces retained
/// values so the reservoir stays a uniform sample of the whole stream —
/// memory is O(cap) no matter how long the run soaks. `mean`, `max` and
/// the observation count stay exact over the full stream (tracked
/// streaming, not from the reservoir); only percentiles become estimates.
/// Replacement uses the repo's deterministic [`Rng`], so a given
/// observation stream always yields the same reservoir.
#[derive(Clone, Debug)]
pub struct Samples {
    xs: Vec<f64>,
    cap: usize,
    /// Total observations pushed (exact, >= xs.len()).
    seen: u64,
    /// Exact streaming sum/max over every observation.
    sum: f64,
    max: f64,
    rng: Rng,
}

impl Default for Samples {
    fn default() -> Self {
        Samples::new()
    }
}

impl Samples {
    pub fn new() -> Self {
        Samples::with_cap(SAMPLES_DEFAULT_CAP)
    }

    /// Reservoir bounded at `cap` retained values (cap >= 1).
    pub fn with_cap(cap: usize) -> Self {
        Samples {
            xs: Vec::new(),
            cap: cap.max(1),
            seen: 0,
            sum: 0.0,
            max: 0.0,
            rng: Rng::new(0x5a3d_7e15_ca11_ab1e),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        self.sum += x;
        self.max = self.max.max(x);
        if self.xs.len() < self.cap {
            self.xs.push(x);
        } else {
            // Algorithm R: the i-th observation replaces a retained slot
            // with probability cap/i, keeping the reservoir uniform.
            let j = self.rng.below(self.seen) as usize;
            if j < self.cap {
                self.xs[j] = x;
            }
        }
    }

    pub fn push_duration(&mut self, d: Duration) {
        self.push(d.as_secs_f64());
    }

    /// Retained reservoir size (== observation count below the cap).
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// Total observations pushed over the stream's lifetime.
    pub fn observed(&self) -> u64 {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.sum / self.seen as f64
        }
    }

    /// Percentile by nearest-rank over the reservoir (q in [0, 100]);
    /// exact while the stream fits the cap, an estimate past it.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut sorted = self.xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Classic nearest-rank: smallest value with cumulative fraction >= q.
        let rank = ((q / 100.0) * sorted.len() as f64).ceil() as usize;
        sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Exact maximum over every observation (not just the reservoir).
    pub fn max(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// The retained reservoir values.
    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

/// Measure a closure's wall time.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

// ---------------------------------------------------------------------------
// Formatting helpers for paper-style bench tables.
// ---------------------------------------------------------------------------

pub fn format_bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

pub fn format_throughput(bytes: u64, d: Duration) -> String {
    let secs = d.as_secs_f64().max(1e-12);
    let bps = bytes as f64 / secs;
    const UNITS: [&str; 5] = ["B/s", "KiB/s", "MiB/s", "GiB/s", "TiB/s"];
    let mut v = bps;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

pub fn format_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basic() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = OnlineStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.variance(), 0.0);
        assert_eq!(s.min(), 0.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.p99(), 99.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn reservoir_bounds_memory() {
        let mut s = Samples::with_cap(512);
        for i in 0..100_000 {
            s.push(i as f64);
        }
        assert_eq!(s.len(), 512);
        assert_eq!(s.observed(), 100_000);
        // Exact streaming stats are unaffected by the bound.
        assert!((s.mean() - 49_999.5).abs() < 1e-6, "mean {}", s.mean());
        assert_eq!(s.max(), 99_999.0);
    }

    #[test]
    fn reservoir_percentiles_stay_accurate() {
        // Uniform stream 0..50k through a 4k reservoir: p50/p95/p99 must
        // land within a few percent of the exact ranks.
        let mut s = Samples::new();
        let n = 50_000usize;
        for i in 0..n {
            s.push(i as f64);
        }
        for (q, exact) in [(50.0, 25_000.0), (95.0, 47_500.0), (99.0, 49_500.0)] {
            let est = s.percentile(q);
            let err = (est - exact).abs() / n as f64;
            assert!(err < 0.03, "p{q}: estimate {est} vs exact {exact}");
        }
    }

    #[test]
    fn reservoir_exact_below_cap() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.len(), 100);
        assert_eq!(s.observed(), 100);
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.p95(), 95.0);
        assert_eq!(s.p99(), 99.0);
    }

    #[test]
    fn reservoir_deterministic() {
        let fill = || {
            let mut s = Samples::with_cap(64);
            for i in 0..10_000 {
                s.push((i * 7 % 997) as f64);
            }
            s.values().to_vec()
        };
        assert_eq!(fill(), fill());
    }

    #[test]
    fn format_helpers() {
        assert_eq!(format_bytes(512), "512 B");
        assert_eq!(format_bytes(2048), "2.00 KiB");
        assert_eq!(format_bytes(3 * 1024 * 1024), "3.00 MiB");
        let s = format_throughput(1024 * 1024, Duration::from_secs(1));
        assert_eq!(s, "1.00 MiB/s");
        assert_eq!(format_duration(Duration::from_millis(5)), "5.000 ms");
    }

    #[test]
    fn time_it_measures() {
        let ((), d) = time_it(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(d >= Duration::from_millis(4));
    }
}
