//! Streaming statistics and measurement helpers (criterion is not available
//! offline; `benches/` builds its harness on top of this module).

use std::time::{Duration, Instant};

/// Welford online mean/variance plus min/max.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }

    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }
}

/// Sample reservoir with exact percentiles (sorts on query).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Samples { xs: Vec::new() }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn push_duration(&mut self, d: Duration) {
        self.xs.push(d.as_secs_f64());
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    /// Exact percentile by nearest-rank (q in [0, 100]).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut sorted = self.xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Classic nearest-rank: smallest value with cumulative fraction >= q.
        let rank = ((q / 100.0) * sorted.len() as f64).ceil() as usize;
        sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(0.0, f64::max)
    }

    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

/// Measure a closure's wall time.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

// ---------------------------------------------------------------------------
// Formatting helpers for paper-style bench tables.
// ---------------------------------------------------------------------------

pub fn format_bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

pub fn format_throughput(bytes: u64, d: Duration) -> String {
    let secs = d.as_secs_f64().max(1e-12);
    let bps = bytes as f64 / secs;
    const UNITS: [&str; 5] = ["B/s", "KiB/s", "MiB/s", "GiB/s", "TiB/s"];
    let mut v = bps;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

pub fn format_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basic() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = OnlineStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.variance(), 0.0);
        assert_eq!(s.min(), 0.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.p99(), 99.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn format_helpers() {
        assert_eq!(format_bytes(512), "512 B");
        assert_eq!(format_bytes(2048), "2.00 KiB");
        assert_eq!(format_bytes(3 * 1024 * 1024), "3.00 MiB");
        let s = format_throughput(1024 * 1024, Duration::from_secs(1));
        assert_eq!(s, "1.00 MiB/s");
        assert_eq!(format_duration(Duration::from_millis(5)), "5.000 ms");
    }

    #[test]
    fn time_it_measures() {
        let ((), d) = time_it(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(d >= Duration::from_millis(4));
    }
}
