//! Minimal JSON parser/serializer (serde is not available offline).
//!
//! Used for: `VelocConfig` files, the AOT `artifacts/manifest.json`,
//! checkpoint version manifests (`modules/version.rs`) and metrics reports.
//! Supports the full JSON grammar; numbers are f64 (adequate: the largest
//! integers we store are byte offsets < 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Maximum container nesting the parser accepts. Every on-disk and wire
/// format embeds a JSON header, so a hostile `[[[[…` document must hit a
/// typed error long before it can exhaust the thread stack through the
/// recursive-descent parser.
pub const MAX_DEPTH: usize = 128;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- constructors -----------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), v.into());
        }
        self
    }

    // ---- accessors --------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["params", "dnn_init", "file"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // convenience typed lookups with default
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    // ---- parse ------------------------------------------------------------
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- serialize ----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    e.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, e)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    e.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    /// Bounded recursion: called on entering a container. Errors abort
    /// the whole parse, so only the `Ok` exits need to unwind `depth`.
    fn enter(&mut self) -> Result<(), ParseError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair
                        if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            s.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        } else {
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk =
                            std::str::from_utf8(&self.b[start..start + len])
                                .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        // Reject overflow-to-infinity (e.g. "1e999"): a non-finite Num
        // would re-serialize as "inf", which no parser reads back — every
        // parsed value must round-trip canonically.
        text.parse::<f64>()
            .ok()
            .filter(|n| n.is_finite())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

/// Load and parse a JSON file.
pub fn load(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"hi\\n\"").unwrap(),
            Json::Str("hi\n".to_string())
        );
    }

    #[test]
    fn overflowing_exponents_are_parse_errors_not_infinities() {
        // "inf" has no JSON spelling, so a value that overflows f64 could
        // never re-serialize canonically — reject it at the door.
        assert!(Json::parse("1e999").is_err());
        assert!(Json::parse("-1e999").is_err());
        assert!(Json::parse("{\"n\":1e999}").is_err());
        // Large-but-finite values still round-trip.
        let j = Json::parse("1e20").unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().idx(2).unwrap().get("b").unwrap(),
                   &Json::Str("c".into()));
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".to_string())
        );
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(
            Json::parse("\"héllo\"").unwrap(),
            Json::Str("héllo".to_string())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn deep_nesting_is_a_parse_error_not_a_stack_overflow() {
        // One past the cap fails; exactly at the cap still parses.
        let over = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        let err = Json::parse(&over).unwrap_err();
        assert!(err.msg.contains("nesting"), "{err}");
        let at = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        assert!(Json::parse(&at).is_ok());
        // Mixed object/array nesting counts every container level.
        let mixed = "{\"k\":[".repeat(80) + &"]}".repeat(80);
        assert!(Json::parse(&mixed).unwrap_err().msg.contains("nesting"));
        // Sibling containers do not accumulate depth.
        let siblings = format!("[{}]", ["[[1]]"; 200].join(","));
        assert!(Json::parse(&siblings).is_ok());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s\"x"],"n":-3,"o":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
        let pretty = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(j, pretty);
    }

    #[test]
    fn builder_and_defaults() {
        let j = Json::obj().set("x", 3usize).set("s", "v").set("b", true);
        assert_eq!(j.usize_or("x", 0), 3);
        assert_eq!(j.usize_or("missing", 7), 7);
        assert_eq!(j.str_or("s", ""), "v");
        assert!(j.bool_or("b", false));
    }

    #[test]
    fn integers_serialized_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap().to_string(), "[]");
        assert_eq!(Json::parse("{}").unwrap().to_string(), "{}");
    }
}
