//! Declarative CLI argument parser (clap is not available offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, defaults and
//! auto-generated `--help`. Used by the `veloc` binary and every example.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct ArgSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Builder + parse result in one struct.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    program: String,
    about: String,
    specs: Vec<ArgSpec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Option with a value and default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(ArgSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Option with a value, no default (required unless absent is OK).
    pub fn opt_req(mut self, name: &str, help: &str) -> Self {
        self.specs.push(ArgSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Boolean flag, defaults to false.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(ArgSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let head = if spec.is_flag {
                format!("  --{}", spec.name)
            } else {
                format!("  --{} <value>", spec.name)
            };
            let def = match &spec.default {
                Some(d) => format!(" [default: {d}]"),
                None => String::new(),
            };
            s.push_str(&format!("{head:<28} {}{def}\n", spec.help));
        }
        s.push_str("  --help                     show this message\n");
        s
    }

    /// Parse an explicit argv (without the program name).
    pub fn parse_from(mut self, args: &[String]) -> Result<Self, String> {
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.help_text());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.help_text()))?
                    .clone();
                let val = if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    "true".to_string()
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    args.get(i)
                        .cloned()
                        .ok_or_else(|| format!("option --{key} needs a value"))?
                };
                self.values.insert(key, val);
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    /// Parse process args; on `--help` or error, print and exit.
    pub fn parse(self) -> Self {
        let args: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(&args) {
            Ok(c) => c,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(if msg.starts_with("unknown") { 2 } else { 0 });
            }
        }
    }

    fn raw(&self, name: &str) -> Option<String> {
        if let Some(v) = self.values.get(name) {
            return Some(v.clone());
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.clone())
    }

    pub fn get(&self, name: &str) -> String {
        self.raw(name)
            .unwrap_or_else(|| panic!("missing required option --{name}"))
    }

    pub fn get_opt(&self, name: &str) -> Option<String> {
        self.raw(name)
    }

    pub fn get_usize(&self, name: &str) -> usize {
        let v = self.get(name);
        v.parse()
            .unwrap_or_else(|_| panic!("--{name}: expected integer, got '{v}'"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        let v = self.get(name);
        v.parse()
            .unwrap_or_else(|_| panic!("--{name}: expected integer, got '{v}'"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        let v = self.get(name);
        v.parse()
            .unwrap_or_else(|_| panic!("--{name}: expected number, got '{v}'"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.raw(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn base() -> Cli {
        Cli::new("t", "test")
            .opt("ranks", "8", "rank count")
            .opt_req("out", "output file")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_apply() {
        let c = base().parse_from(&argv(&["--out", "x"])).unwrap();
        assert_eq!(c.get_usize("ranks"), 8);
        assert_eq!(c.get("out"), "x");
        assert!(!c.get_bool("verbose"));
    }

    #[test]
    fn overrides_and_flags() {
        let c = base()
            .parse_from(&argv(&["--ranks=32", "--out", "y", "--verbose"]))
            .unwrap();
        assert_eq!(c.get_usize("ranks"), 32);
        assert!(c.get_bool("verbose"));
    }

    #[test]
    fn unknown_option_errors() {
        assert!(base().parse_from(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(base().parse_from(&argv(&["--ranks"])).is_err());
    }

    #[test]
    fn help_lists_options() {
        let err = base().parse_from(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("--ranks"));
        assert!(err.contains("rank count"));
    }

    #[test]
    fn positional_collected() {
        let c = base().parse_from(&argv(&["--out", "x", "cmd"])).unwrap();
        assert_eq!(c.positional(), &["cmd".to_string()]);
    }
}
