//! Deterministic PRNG: SplitMix64-seeded xoshiro256**.
//!
//! The crates.io `rand` facade is not available offline, and determinism
//! matters here anyway: failure injection, workload generation and the
//! interval-optimizer dataset must be reproducible from a single seed so
//! benches regenerate the same paper-style rows run over run.

/// xoshiro256** by Blackman & Vigna (public domain reference rewritten).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    gauss: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss: None }
    }

    /// Derive an independent stream (e.g. one per rank) from this one.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(g) = self.gauss.take() {
            return g;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Exponential with the given rate (1/mean). Used by the MTBF failure
    /// process of `cluster::failure`.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let mut u = self.f64();
        if u == 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -u.ln() / rate
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill a byte slice (checkpoint payload generation).
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(3);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(17);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
