//! Word-parallel data-plane kernels: CRC32 (slice-by-16) and the
//! lane-structured 64-bit payload hash behind delta fingerprints.
//!
//! Every fast kernel here has a bit-identical scalar reference next to it
//! (`*_scalar`), property-tested across odd lengths, misaligned offsets
//! and empty/1-byte inputs. The fast paths use no intrinsics — just table
//! slicing and independent dependency chains the compiler turns into wide
//! loads and ILP — so they are portable and Miri-clean.

/// CRC-32 (IEEE, reflected, poly 0xEDB88320) — the same polynomial as
/// `crc32fast::hash`, verified by property test.
pub const CRC32_POLY: u32 = 0xEDB8_8320;

/// How many bytes each slice-by-16 step consumes.
const CRC_STRIDE: usize = 16;

fn crc_tables() -> &'static [[u32; 256]; CRC_STRIDE] {
    use std::sync::OnceLock;
    static TABLES: OnceLock<Box<[[u32; 256]; CRC_STRIDE]>> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut t = Box::new([[0u32; 256]; CRC_STRIDE]);
        for i in 0..256u32 {
            let mut c = i;
            for _ in 0..8 {
                c = if c & 1 != 0 {
                    (c >> 1) ^ CRC32_POLY
                } else {
                    c >> 1
                };
            }
            t[0][i as usize] = c;
        }
        for i in 0..256 {
            let mut c = t[0][i];
            for k in 1..CRC_STRIDE {
                c = t[0][(c & 0xff) as usize] ^ (c >> 8);
                t[k][i] = c;
            }
        }
        t
    })
}

/// Byte-serial table CRC32 — the scalar baseline the benches gate against.
pub fn crc32_scalar(data: &[u8]) -> u32 {
    let t = &crc_tables()[0];
    let mut c = !0u32;
    for &b in data {
        c = t[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

/// Slice-by-16 CRC32: one table lookup per byte but sixteen independent
/// lookups per step feeding two 64-bit loads, so the serial dependency is
/// one XOR-fold per 16 bytes instead of per byte.
pub fn crc32_wide(data: &[u8]) -> u32 {
    let t = crc_tables();
    let mut c = !0u32;
    let mut chunks = data.chunks_exact(CRC_STRIDE);
    for chunk in &mut chunks {
        let lo = u64::from_le_bytes(chunk[0..8].try_into().unwrap()) ^ c as u64;
        let hi = u64::from_le_bytes(chunk[8..16].try_into().unwrap());
        c = t[15][(lo & 0xff) as usize]
            ^ t[14][((lo >> 8) & 0xff) as usize]
            ^ t[13][((lo >> 16) & 0xff) as usize]
            ^ t[12][((lo >> 24) & 0xff) as usize]
            ^ t[11][((lo >> 32) & 0xff) as usize]
            ^ t[10][((lo >> 40) & 0xff) as usize]
            ^ t[9][((lo >> 48) & 0xff) as usize]
            ^ t[8][((lo >> 56) & 0xff) as usize]
            ^ t[7][(hi & 0xff) as usize]
            ^ t[6][((hi >> 8) & 0xff) as usize]
            ^ t[5][((hi >> 16) & 0xff) as usize]
            ^ t[4][((hi >> 24) & 0xff) as usize]
            ^ t[3][((hi >> 32) & 0xff) as usize]
            ^ t[2][((hi >> 40) & 0xff) as usize]
            ^ t[1][((hi >> 48) & 0xff) as usize]
            ^ t[0][((hi >> 56) & 0xff) as usize];
    }
    let t0 = &t[0];
    for &b in chunks.remainder() {
        c = t0[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

/// Lane seeds for [`fp_hash64`]: four distinct odd 64-bit constants
/// (splitmix64 outputs of 1..=4) so the lanes never collapse together.
const FP_LANE_SEEDS: [u64; 4] = [
    0x910A_2DEC_8902_5CC1,
    0xBEEB_D1A8_9EA5_3222,
    0xF7FB_1E68_E991_BBD5,
    0x7055_E409_3D4F_70F0,
];
const FP_MUL: u64 = 0x9E37_79B9_7F4A_7C15;

#[inline]
fn fp_mix(mut x: u64) -> u64 {
    // splitmix64 finalizer — full avalanche.
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[inline]
fn fp_lane_step(lane: u64, word: u64) -> u64 {
    (lane ^ word).wrapping_mul(FP_MUL).rotate_left(29)
}

/// Scalar reference for the payload fingerprint hash: four logical lanes
/// fed 8-byte little-endian words round-robin, tail bytes zero-padded into
/// a final word tagged with the tail length, lanes cross-mixed at the end.
/// The definition is lane-structured on purpose — see [`fp_hash64`].
pub fn fp_hash64_scalar(data: &[u8]) -> u64 {
    let mut lanes = FP_LANE_SEEDS;
    let mut word_idx = 0usize;
    let mut chunks = data.chunks_exact(8);
    for chunk in &mut chunks {
        let w = u64::from_le_bytes(chunk.try_into().unwrap());
        lanes[word_idx & 3] = fp_lane_step(lanes[word_idx & 3], w);
        word_idx += 1;
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut tail = [0u8; 8];
        tail[..rem.len()].copy_from_slice(rem);
        let w = u64::from_le_bytes(tail) ^ ((rem.len() as u64) << 56);
        lanes[word_idx & 3] = fp_lane_step(lanes[word_idx & 3], w);
    }
    let mut h = data.len() as u64;
    for (i, l) in lanes.iter().enumerate() {
        h = h.wrapping_mul(FP_MUL) ^ fp_mix(l.rotate_left(i as u32 * 7));
    }
    fp_mix(h)
}

/// Fast payload fingerprint hash, bit-identical to [`fp_hash64_scalar`].
/// Processes 32 bytes per step as four independent multiply chains — the
/// ILP the byte-serial FNV loop it replaced could never expose (FNV's
/// next-state depends on every prior byte; four lanes only depend on
/// every fourth word).
pub fn fp_hash64(data: &[u8]) -> u64 {
    let mut lanes = FP_LANE_SEEDS;
    let mut chunks32 = data.chunks_exact(32);
    for c in &mut chunks32 {
        lanes[0] = fp_lane_step(lanes[0], u64::from_le_bytes(c[0..8].try_into().unwrap()));
        lanes[1] = fp_lane_step(lanes[1], u64::from_le_bytes(c[8..16].try_into().unwrap()));
        lanes[2] = fp_lane_step(lanes[2], u64::from_le_bytes(c[16..24].try_into().unwrap()));
        lanes[3] = fp_lane_step(lanes[3], u64::from_le_bytes(c[24..32].try_into().unwrap()));
    }
    let rem = chunks32.remainder();
    let mut word_idx = (data.len() / 32) * 4;
    let mut tail_words = rem.chunks_exact(8);
    for chunk in &mut tail_words {
        let w = u64::from_le_bytes(chunk.try_into().unwrap());
        lanes[word_idx & 3] = fp_lane_step(lanes[word_idx & 3], w);
        word_idx += 1;
    }
    let last = tail_words.remainder();
    if !last.is_empty() {
        let mut tail = [0u8; 8];
        tail[..last.len()].copy_from_slice(last);
        let w = u64::from_le_bytes(tail) ^ ((last.len() as u64) << 56);
        lanes[word_idx & 3] = fp_lane_step(lanes[word_idx & 3], w);
    }
    let mut h = data.len() as u64;
    for (i, l) in lanes.iter().enumerate() {
        h = h.wrapping_mul(FP_MUL) ^ fp_mix(l.rotate_left(i as u32 * 7));
    }
    fp_mix(h)
}

/// Byte-serial FNV-1a64 — the *legacy* fingerprint hash, kept only as the
/// scalar baseline the delta bench gates `fp_hash64` against (and for
/// decoding nothing: fingerprints are self-consistent per repo version).
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lens() -> Vec<usize> {
        vec![0, 1, 2, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 100, 1000, 4097]
    }

    fn fill(n: usize, seed: u64) -> Vec<u8> {
        let mut s = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        (0..n)
            .map(|_| {
                s ^= s >> 12;
                s ^= s << 25;
                s ^= s >> 27;
                (s.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 56) as u8
            })
            .collect()
    }

    #[test]
    fn crc_wide_matches_scalar_and_crc32fast() {
        for (i, n) in lens().into_iter().enumerate() {
            let data = fill(n, i as u64);
            let s = crc32_scalar(&data);
            let w = crc32_wide(&data);
            assert_eq!(s, w, "len {n}");
            assert_eq!(w, crc32fast::hash(&data), "len {n} vs crc32fast");
            // Misaligned view of the same data.
            if n > 3 {
                assert_eq!(crc32_scalar(&data[3..]), crc32_wide(&data[3..]));
            }
        }
    }

    #[test]
    fn fp_hash_matches_scalar_reference() {
        for (i, n) in lens().into_iter().enumerate() {
            let data = fill(n, 100 + i as u64);
            assert_eq!(fp_hash64(&data), fp_hash64_scalar(&data), "len {n}");
            if n > 5 {
                assert_eq!(
                    fp_hash64(&data[5..]),
                    fp_hash64_scalar(&data[5..]),
                    "misaligned len {n}"
                );
            }
        }
    }

    #[test]
    fn fp_hash_separates_lengths_and_contents() {
        // Zero-padded tails must not collide with actual zero bytes.
        assert_ne!(fp_hash64(b"abc"), fp_hash64(b"abc\0"));
        assert_ne!(fp_hash64(b""), fp_hash64(b"\0"));
        assert_ne!(fp_hash64(b"abcdefgh"), fp_hash64(b"abcdefgi"));
    }
}
