//! Priority worker pool — the execution engine behind VeloC's *active
//! backend* (tokio is not available offline; OS threads also match the real
//! VeloC design, whose backend is a separate process/thread, not async).
//!
//! Jobs carry a [`Priority`]; the paper's interference-mitigation strategy
//! ("run background operations with lower priority", §2) maps to
//! `Priority::Background` jobs that (a) sort after foreground work in the
//! queue and (b) optionally self-throttle between chunks via the pool's
//! `nice_sleep` knob (the micro-benchmark-calibrated time-slice model).

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Application-blocking work (e.g. capture to the fastest tier).
    Foreground = 2,
    /// Ordinary async pipeline stages.
    Normal = 1,
    /// Interference-mitigated background flushes.
    Background = 0,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

struct QueuedJob {
    prio: Priority,
    seq: u64, // FIFO within a priority class (smaller = older)
    job: Job,
}

impl PartialEq for QueuedJob {
    fn eq(&self, other: &Self) -> bool {
        self.prio == other.prio && self.seq == other.seq
    }
}
impl Eq for QueuedJob {}
impl PartialOrd for QueuedJob {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueuedJob {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Max-heap: higher priority first, then older seq first.
        self.prio
            .cmp(&other.prio)
            .then(other.seq.cmp(&self.seq))
    }
}

struct PoolState {
    queue: BinaryHeap<QueuedJob>,
    shutdown: bool,
    active: usize,
}

struct Shared {
    state: Mutex<PoolState>,
    cv: Condvar,
    idle_cv: Condvar,
    seq: AtomicU64,
}

/// Completion handle for a submitted job.
pub struct JobHandle {
    done: Arc<(Mutex<bool>, Condvar)>,
}

impl JobHandle {
    pub fn wait(&self) {
        let (lock, cv) = &*self.done;
        let mut done = lock.lock().unwrap();
        while !*done {
            done = cv.wait(done).unwrap();
        }
    }

    pub fn wait_timeout(&self, d: Duration) -> bool {
        let (lock, cv) = &*self.done;
        let mut done = lock.lock().unwrap();
        let deadline = std::time::Instant::now() + d;
        while !*done {
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (g, _timeout) = cv.wait_timeout(done, deadline - now).unwrap();
            done = g;
        }
        true
    }

    pub fn is_done(&self) -> bool {
        *self.done.0.lock().unwrap()
    }
}

/// Fixed-size priority thread pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    paused: Arc<AtomicBool>,
}

impl ThreadPool {
    pub fn new(workers: usize) -> Self {
        Self::with_nice(workers, 0)
    }

    /// Pool whose worker threads run at the given OS nice level. This is
    /// the paper's second mitigation strategy verbatim: "the background
    /// operations can be scheduled such that they run with lower priority
    /// [and] the operating system will reduce contention by giving the
    /// application a large time slice".
    pub fn with_nice(workers: usize, nice: i32) -> Self {
        assert!(workers > 0);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                queue: BinaryHeap::new(),
                shutdown: false,
                active: 0,
            }),
            cv: Condvar::new(),
            idle_cv: Condvar::new(),
            seq: AtomicU64::new(0),
        });
        let paused = Arc::new(AtomicBool::new(false));
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                let pa = Arc::clone(&paused);
                std::thread::Builder::new()
                    .name(format!("veloc-backend-{i}"))
                    .spawn(move || {
                        set_thread_nice(nice);
                        worker_loop(sh, pa)
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            shared,
            workers: handles,
            paused,
        }
    }

    pub fn submit<F>(&self, prio: Priority, f: F) -> JobHandle
    where
        F: FnOnce() + Send + 'static,
    {
        let done = Arc::new((Mutex::new(false), Condvar::new()));
        let done2 = Arc::clone(&done);
        let job: Job = Box::new(move || {
            f();
            let (lock, cv) = &*done2;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        });
        let seq = self.shared.seq.fetch_add(1, Ordering::Relaxed);
        {
            let mut st = self.shared.state.lock().unwrap();
            assert!(!st.shutdown, "submit after shutdown");
            st.queue.push(QueuedJob { prio, seq, job });
        }
        self.shared.cv.notify_one();
        JobHandle { done }
    }

    /// Number of queued (not yet started) jobs.
    pub fn queued(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    /// Jobs currently executing.
    pub fn active(&self) -> usize {
        self.shared.state.lock().unwrap().active
    }

    /// Block until queue is empty and all workers idle.
    pub fn wait_idle(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while !st.queue.is_empty() || st.active > 0 {
            st = self.shared.idle_cv.wait(st).unwrap();
        }
    }

    /// Pause/resume dequeueing of *Background* jobs (the scheduler's lever:
    /// predicted-busy phases suspend background flushes entirely).
    pub fn pause_background(&self, paused: bool) {
        self.paused.store(paused, Ordering::SeqCst);
        self.shared.cv.notify_all();
    }

    pub fn background_paused(&self) -> bool {
        self.paused.load(Ordering::SeqCst)
    }

    /// Kill the pool: stop the workers and *drop* every queued job without
    /// running it. Jobs already executing finish; everything still in the
    /// queue is discarded. This models a process crash (the backend
    /// daemon's fault-injection path) — a graceful drop runs the queue dry
    /// instead. Idempotent; `submit` after `kill` panics like submit after
    /// shutdown.
    pub fn kill(&self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            st.queue.clear();
        }
        self.shared.cv.notify_all();
        self.shared.idle_cv.notify_all();
    }
}

/// Lower the calling thread's scheduling priority (Linux: per-thread nice
/// via setpriority on the tid; no-op elsewhere or on failure — priority is
/// an optimization, not a correctness requirement).
fn set_thread_nice(nice: i32) {
    if nice == 0 {
        return;
    }
    #[cfg(target_os = "linux")]
    unsafe {
        let tid = libc::syscall(libc::SYS_gettid) as libc::id_t;
        let _ = libc::setpriority(libc::PRIO_PROCESS, tid, nice);
    }
    #[cfg(not(target_os = "linux"))]
    let _ = nice;
}

fn worker_loop(sh: Arc<Shared>, paused: Arc<AtomicBool>) {
    loop {
        let job = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if st.shutdown && st.queue.is_empty() {
                    return;
                }
                let bg_paused = paused.load(Ordering::SeqCst);
                // If background is paused and only background jobs remain,
                // keep waiting (with a timeout so resume is prompt).
                let runnable = st
                    .queue
                    .peek()
                    .map(|q| !(bg_paused && q.prio == Priority::Background))
                    .unwrap_or(false);
                if runnable {
                    let q = st.queue.pop().unwrap();
                    st.active += 1;
                    break q.job;
                }
                let (g, _t) = sh
                    .cv
                    .wait_timeout(st, Duration::from_millis(20))
                    .unwrap();
                st = g;
            }
        };
        job();
        let mut st = sh.state.lock().unwrap();
        st.active -= 1;
        if st.queue.is_empty() && st.active == 0 {
            sh.idle_cv.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..32)
            .map(|_| {
                let c = Arc::clone(&counter);
                pool.submit(Priority::Normal, move || {
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.wait();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn priority_ordering_single_worker() {
        let pool = ThreadPool::new(1);
        let order = Arc::new(Mutex::new(Vec::new()));
        // Occupy the worker so the queue builds up.
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g2 = Arc::clone(&gate);
        let blocker = pool.submit(Priority::Foreground, move || {
            let (l, cv) = &*g2;
            let mut open = l.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        });
        let push = |p: Priority, tag: &'static str| {
            let o = Arc::clone(&order);
            pool.submit(p, move || o.lock().unwrap().push(tag))
        };
        let h1 = push(Priority::Background, "bg");
        let h2 = push(Priority::Foreground, "fg");
        let h3 = push(Priority::Normal, "norm");
        // Open the gate.
        {
            let (l, cv) = &*gate;
            *l.lock().unwrap() = true;
            cv.notify_all();
        }
        blocker.wait();
        h1.wait();
        h2.wait();
        h3.wait();
        assert_eq!(*order.lock().unwrap(), vec!["fg", "norm", "bg"]);
    }

    #[test]
    fn wait_idle_drains() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let c = Arc::clone(&counter);
            pool.submit(Priority::Normal, move || {
                std::thread::sleep(Duration::from_millis(2));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 16);
        assert_eq!(pool.queued(), 0);
    }

    #[test]
    fn pause_background_defers_bg_jobs() {
        let pool = ThreadPool::new(1);
        pool.pause_background(true);
        let ran = Arc::new(AtomicUsize::new(0));
        let r2 = Arc::clone(&ran);
        let h = pool.submit(Priority::Background, move || {
            r2.fetch_add(1, Ordering::SeqCst);
        });
        assert!(!h.wait_timeout(Duration::from_millis(80)));
        assert_eq!(ran.load(Ordering::SeqCst), 0);
        pool.pause_background(false);
        h.wait();
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn fifo_within_class() {
        let pool = ThreadPool::new(1);
        let order = Arc::new(Mutex::new(Vec::new()));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g2 = Arc::clone(&gate);
        pool.submit(Priority::Foreground, move || {
            let (l, cv) = &*g2;
            let mut open = l.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        });
        let hs: Vec<_> = (0..5)
            .map(|i| {
                let o = Arc::clone(&order);
                pool.submit(Priority::Normal, move || {
                    o.lock().unwrap().push(i)
                })
            })
            .collect();
        {
            let (l, cv) = &*gate;
            *l.lock().unwrap() = true;
            cv.notify_all();
        }
        for h in hs {
            h.wait();
        }
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn kill_drops_queued_jobs() {
        let pool = ThreadPool::new(1);
        let ran = Arc::new(AtomicUsize::new(0));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g2 = Arc::clone(&gate);
        let blocker = pool.submit(Priority::Foreground, move || {
            let (l, cv) = &*g2;
            let mut open = l.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        });
        let queued: Vec<_> = (0..8)
            .map(|_| {
                let r = Arc::clone(&ran);
                pool.submit(Priority::Normal, move || {
                    r.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        pool.kill();
        // Unblock the in-flight job; it finishes, the queued ones do not.
        {
            let (l, cv) = &*gate;
            *l.lock().unwrap() = true;
            cv.notify_all();
        }
        blocker.wait();
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(ran.load(Ordering::SeqCst), 0, "killed jobs must not run");
        assert!(queued.iter().all(|h| !h.is_done()));
    }

    #[test]
    fn handle_timeout_and_done() {
        let pool = ThreadPool::new(1);
        let h = pool.submit(Priority::Normal, || {
            std::thread::sleep(Duration::from_millis(30))
        });
        assert!(!h.wait_timeout(Duration::from_millis(1)));
        assert!(h.wait_timeout(Duration::from_secs(5)));
        assert!(h.is_done());
    }
}
