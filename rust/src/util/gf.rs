//! GF(2^8) arithmetic and Reed-Solomon coding (poly 0x11d), with a
//! word-parallel slice-multiply generalizing the `xor_fold_wide`
//! `align_to::<u64>` trick to Galois multiplication.
//!
//! The pipeline's level-3 module ships single-parity XOR (RAID-5); this
//! module supplies the general m-parity math the roadmap's multi-failure
//! erasure needs, and its wide kernel is one of the gated bench baselines.
//!
//! The wide multiply works on eight field elements packed in a `u64`:
//! doubling all eight lanes at once is
//! `hi = t & 0x8080..; ((t ^ hi) << 1) ^ ((hi >> 7) * 0x1d)` — the
//! carry-conditional reduction done branch-free per lane — and multiplying
//! by an arbitrary constant iterates the set bits of the constant over a
//! running doubled value (at most 8 doublings per 8 bytes).

use std::sync::OnceLock;

/// The field polynomial: x^8 + x^4 + x^3 + x^2 + 1.
pub const GF_POLY: u16 = 0x11d;

struct Tables {
    log: [u8; 256],
    exp: [u8; 512],
}

fn tables() -> &'static Tables {
    static T: OnceLock<Tables> = OnceLock::new();
    T.get_or_init(|| {
        let mut t = Tables {
            log: [0; 256],
            exp: [0; 512],
        };
        let mut x = 1u16;
        for i in 0..255 {
            t.exp[i] = x as u8;
            t.exp[i + 255] = x as u8; // duplicated so mul skips the % 255
            t.log[x as usize] = i as u8;
            x <<= 1;
            if x & 0x100 != 0 {
                x ^= GF_POLY;
            }
        }
        t.exp[510] = t.exp[255];
        t.exp[511] = t.exp[256];
        t
    })
}

/// Multiply two field elements (log/exp tables).
#[inline]
pub fn gf_mul(a: u8, b: u8) -> u8 {
    if a == 0 || b == 0 {
        return 0;
    }
    let t = tables();
    t.exp[t.log[a as usize] as usize + t.log[b as usize] as usize]
}

/// Multiplicative inverse; panics on zero (no inverse exists).
pub fn gf_inv(a: u8) -> u8 {
    assert!(a != 0, "gf_inv(0)");
    let t = tables();
    t.exp[255 - t.log[a as usize] as usize]
}

/// `x^p` for generator x = 2.
pub fn gf_exp(p: usize) -> u8 {
    tables().exp[p % 255]
}

/// `acc[i] ^= c * src[i]` — byte-at-a-time baseline the bench gates against.
pub fn gf_mul_slice_scalar(acc: &mut [u8], src: &[u8], c: u8) {
    assert_eq!(acc.len(), src.len());
    if c == 0 {
        return;
    }
    if c == 1 {
        for (a, &s) in acc.iter_mut().zip(src) {
            *a ^= s;
        }
        return;
    }
    let t = tables();
    let lc = t.log[c as usize] as usize;
    for (a, &s) in acc.iter_mut().zip(src) {
        if s != 0 {
            *a ^= t.exp[lc + t.log[s as usize] as usize];
        }
    }
}

/// Double all eight packed lanes: per-byte `t*2` in GF(2^8).
#[inline]
fn gf2_wide(t: u64) -> u64 {
    let hi = t & 0x8080_8080_8080_8080;
    ((t ^ hi) << 1) ^ ((hi >> 7).wrapping_mul(0x1d))
}

/// Multiply eight packed lanes by constant `c` (iterate c's set bits over
/// a running doubled value — shift-and-add in the field).
#[inline]
fn gf_mul_wide_word(mut t: u64, mut c: u8) -> u64 {
    let mut out = 0u64;
    while c != 0 {
        if c & 1 != 0 {
            out ^= t;
        }
        c >>= 1;
        if c != 0 {
            t = gf2_wide(t);
        }
    }
    out
}

/// `acc[i] ^= c * src[i]`, eight lanes per step. Bit-identical to
/// [`gf_mul_slice_scalar`] (property-tested); handles unaligned heads and
/// tails byte-wise like `xor_fold_wide`.
pub fn gf_mul_slice_wide(acc: &mut [u8], src: &[u8], c: u8) {
    assert_eq!(acc.len(), src.len());
    if c == 0 {
        return;
    }
    // SAFETY: u64 has no invalid bit patterns and align_to yields
    // correctly aligned, in-bounds subslices; head/tail are handled
    // byte-wise below.
    let (head, body, tail) = unsafe { acc.align_to_mut::<u64>() };
    let h = head.len();
    gf_mul_slice_scalar(head, &src[..h], c);
    let body_bytes = body.len() * 8;
    for (i, w) in body.iter_mut().enumerate() {
        let s = u64::from_ne_bytes(src[h + i * 8..h + i * 8 + 8].try_into().unwrap());
        *w ^= gf_mul_wide_word(s, c);
    }
    gf_mul_slice_scalar(tail, &src[h + body_bytes..], c);
}

/// Encode `m` parity shards over `k` data shards (all `shard_len` long)
/// with the Vandermonde matrix `coef[p][d] = (d+1)^p`: parity row 0 is the
/// plain XOR the level-3 module ships, higher rows weight each data shard
/// by a distinct power so any `m` erasures stay solvable.
pub fn rs_encode(data: &[&[u8]], m: usize) -> Vec<Vec<u8>> {
    assert!(!data.is_empty(), "rs_encode: no data shards");
    let len = data[0].len();
    assert!(
        data.iter().all(|d| d.len() == len),
        "rs_encode: unequal shard lengths"
    );
    let mut parities = vec![vec![0u8; len]; m];
    for (p, parity) in parities.iter_mut().enumerate() {
        for (d, shard) in data.iter().enumerate() {
            let c = coef(p, d);
            gf_mul_slice_wide(parity, shard, c);
        }
    }
    parities
}

/// `coef(p, d) = (d+1)^p` — data shard d's weight in parity row p.
fn coef(p: usize, d: usize) -> u8 {
    let mut c = 1u8;
    for _ in 0..p {
        c = gf_mul(c, (d + 1) as u8);
    }
    c
}

/// Reconstruct the missing data shards in place. `shards[d]` is `Some`
/// for survivors; `parities[p]` likewise. Returns `None` when more shards
/// are missing than parities survive, or when the surviving equation
/// system is singular (Vandermonde parities are MDS for m <= 2, which is
/// all the pipeline configures; beyond that solvability is checked, not
/// assumed).
pub fn rs_reconstruct(
    shards: &mut [Option<Vec<u8>>],
    parities: &[Option<Vec<u8>>],
    shard_len: usize,
) -> Option<()> {
    let missing: Vec<usize> = (0..shards.len()).filter(|&i| shards[i].is_none()).collect();
    if missing.is_empty() {
        return Some(());
    }
    let avail: Vec<usize> = (0..parities.len())
        .filter(|&p| parities[p].is_some())
        .collect();
    if missing.len() > avail.len() {
        return None;
    }
    let n = missing.len();
    // Rows: one surviving parity equation each, knowns folded into rhs:
    //   sum_j coef(p, missing[j]) * x_j = parity_p ^ sum_{known d} coef(p,d)*shard_d
    let mut mat = vec![vec![0u8; n]; n];
    let mut rhs: Vec<Vec<u8>> = Vec::with_capacity(n);
    for (row, &p) in avail.iter().take(n).enumerate() {
        for (col, &d) in missing.iter().enumerate() {
            mat[row][col] = coef(p, d);
        }
        let mut r = parities[p].clone().unwrap();
        r.resize(shard_len, 0);
        for (d, s) in shards.iter().enumerate() {
            if let Some(s) = s {
                // Reconstruction is cold: clone-and-pad survivors rather
                // than juggling borrowed padded views.
                let mut src = s.clone();
                src.resize(shard_len, 0);
                gf_mul_slice_wide(&mut r, &src, coef(p, d));
            }
        }
        rhs.push(r);
    }
    // Gaussian elimination over GF(2^8).
    for col in 0..n {
        let pivot = (col..n).find(|&r| mat[r][col] != 0)?;
        mat.swap(col, pivot);
        rhs.swap(col, pivot);
        let inv = gf_inv(mat[col][col]);
        for x in mat[col][col..].iter_mut() {
            *x = gf_mul(*x, inv);
        }
        let (pr, prhs) = (mat[col].clone(), rhs[col].clone());
        for r in 0..n {
            if r != col && mat[r][col] != 0 {
                let f = mat[r][col];
                for (x, &pc) in mat[r][col..].iter_mut().zip(&pr[col..]) {
                    *x ^= gf_mul(f, pc);
                }
                let row = &mut rhs[r];
                gf_mul_slice_wide(row, &prhs, f);
            }
        }
    }
    for (j, &d) in missing.iter().enumerate() {
        shards[d] = Some(std::mem::take(&mut rhs[j]));
    }
    Some(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(n: usize, seed: u64) -> Vec<u8> {
        let mut s = seed.wrapping_add(0x1234_5678_9ABC_DEF0) | 1;
        (0..n)
            .map(|_| {
                s ^= s >> 12;
                s ^= s << 25;
                s ^= s >> 27;
                (s.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 56) as u8
            })
            .collect()
    }

    #[test]
    fn field_axioms() {
        assert_eq!(gf_mul(0, 7), 0);
        assert_eq!(gf_mul(1, 7), 7);
        for a in 1..=255u8 {
            assert_eq!(gf_mul(a, gf_inv(a)), 1, "a={a}");
            for b in 1..=10u8 {
                assert_eq!(gf_mul(a, b), gf_mul(b, a));
            }
        }
        // 0x1d reduction sanity: 0x80 * 2 = 0x1d.
        assert_eq!(gf_mul(0x80, 2), 0x1d);
    }

    #[test]
    fn wide_mul_matches_scalar() {
        for n in [0usize, 1, 7, 8, 9, 15, 16, 63, 64, 65, 1000] {
            for c in [0u8, 1, 2, 3, 0x1d, 0x80, 0xff] {
                let src = fill(n, n as u64 + c as u64);
                let mut a1 = fill(n, 999);
                let mut a2 = a1.clone();
                gf_mul_slice_scalar(&mut a1, &src, c);
                gf_mul_slice_wide(&mut a2, &src, c);
                assert_eq!(a1, a2, "n={n} c={c}");
                // Misaligned destination view.
                if n > 3 {
                    let mut b1 = fill(n, 7);
                    let mut b2 = b1.clone();
                    gf_mul_slice_scalar(&mut b1[3..], &src[3..], c);
                    gf_mul_slice_wide(&mut b2[3..], &src[3..], c);
                    assert_eq!(b1, b2, "misaligned n={n} c={c}");
                }
            }
        }
    }

    #[test]
    fn rs_roundtrip_all_two_erasure_patterns() {
        let k = 5;
        let m = 2;
        let len = 1031; // odd on purpose
        let shards: Vec<Vec<u8>> = (0..k).map(|i| fill(len, i as u64)).collect();
        let refs: Vec<&[u8]> = shards.iter().map(|s| s.as_slice()).collect();
        let parities = rs_encode(&refs, m);
        for lose_a in 0..k {
            for lose_b in lose_a + 1..k {
                let mut have: Vec<Option<Vec<u8>>> =
                    shards.iter().cloned().map(Some).collect();
                have[lose_a] = None;
                have[lose_b] = None;
                let pav: Vec<Option<Vec<u8>>> = parities.iter().cloned().map(Some).collect();
                rs_reconstruct(&mut have, &pav, len).expect("solvable");
                assert_eq!(have[lose_a].as_ref().unwrap(), &shards[lose_a]);
                assert_eq!(have[lose_b].as_ref().unwrap(), &shards[lose_b]);
            }
        }
    }

    #[test]
    fn rs_parity_row_zero_is_plain_xor() {
        let a = fill(100, 1);
        let b = fill(100, 2);
        let parities = rs_encode(&[&a, &b], 1);
        let xor: Vec<u8> = a.iter().zip(&b).map(|(x, y)| x ^ y).collect();
        assert_eq!(parities[0], xor);
    }

    #[test]
    fn rs_too_many_erasures_unsolvable() {
        let shards: Vec<Vec<u8>> = (0..4).map(|i| fill(64, i as u64)).collect();
        let refs: Vec<&[u8]> = shards.iter().map(|s| s.as_slice()).collect();
        let parities = rs_encode(&refs, 1);
        let mut have: Vec<Option<Vec<u8>>> = shards.iter().cloned().map(Some).collect();
        have[0] = None;
        have[2] = None;
        let pav: Vec<Option<Vec<u8>>> = parities.into_iter().map(Some).collect();
        assert!(rs_reconstruct(&mut have, &pav, 64).is_none());
    }
}
