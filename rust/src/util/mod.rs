//! From-scratch substrates: JSON, PRNG, CLI, priority thread pool,
//! statistics, and the VCKP checkpoint container format.
//!
//! These exist because the offline crate set has no serde/clap/rand/tokio/
//! criterion — and because determinism and priority semantics are part of
//! the system's contract (see DESIGN.md §System inventory).

pub mod bufpool;
pub mod bytes;
pub mod cli;
pub mod gf;
pub mod json;
pub mod kernels;
pub mod pool;
pub mod rng;
pub mod stats;
