//! Fault-injection instrumentation: the shared death ledger every hook
//! reports into, plus the chunk-counting fault gate that lands failures
//! mid-transfer / mid-aggregation-drain.
//!
//! Design rule for determinism: hooks only *mark ranks dead* (and abort
//! their in-flight pipelines); the actual storage wipe is always performed
//! by the single-threaded scenario runner after the checkpoint wave
//! settles, so the observable end state never depends on thread timing.

use crate::modules::FlushGate;
use crate::pipeline::{BoundaryHook, CkptContext};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::{Arc, Mutex};

/// Boundary plan: the victim ranks die right before `module` runs for
/// checkpoint `version` — the "failure lands mid-pipeline" family.
#[derive(Clone, Debug)]
pub struct BoundaryPlan {
    pub module: String,
    pub version: u64,
    pub victims: Vec<usize>,
}

/// Shared fault ledger. Implements [`BoundaryHook`]: a dead rank's pipeline
/// aborts at the next module boundary (its process no longer exists), and
/// the levels it had completed at death are recorded so the scenario
/// engine can compute the exact recoverability expectation.
#[derive(Default)]
pub struct FaultState {
    dead: Mutex<BTreeSet<usize>>,
    /// rank -> (version it died in, levels completed at death).
    at_death: Mutex<BTreeMap<usize, (u64, Vec<u8>)>>,
    plan: Mutex<Option<BoundaryPlan>>,
}

impl FaultState {
    pub fn new() -> Arc<Self> {
        Arc::new(FaultState::default())
    }

    /// Arm a module-boundary death plan.
    pub fn set_plan(&self, plan: BoundaryPlan) {
        *self.plan.lock().unwrap() = Some(plan);
    }

    /// Mark ranks dead (called by the fault gate / aggregation fault hook
    /// at the instant the simulated failure lands).
    pub fn kill_all(&self, ranks: &[usize]) {
        let mut dead = self.dead.lock().unwrap();
        for &r in ranks {
            dead.insert(r);
        }
    }

    pub fn is_dead(&self, rank: usize) -> bool {
        self.dead.lock().unwrap().contains(&rank)
    }

    pub fn dead_ranks(&self) -> Vec<usize> {
        self.dead.lock().unwrap().iter().copied().collect()
    }

    /// Levels a rank had completed when it died, if its pipeline was cut
    /// short (ranks that died between commands have no entry — their
    /// registry records are complete).
    pub fn death_levels(&self, rank: usize) -> Option<(u64, Vec<u8>)> {
        self.at_death.lock().unwrap().get(&rank).cloned()
    }

    fn record_death(&self, ctx: &CkptContext) {
        self.at_death
            .lock()
            .unwrap()
            .entry(ctx.rank)
            .or_insert_with(|| {
                let mut levels: Vec<u8> = ctx
                    .results
                    .iter()
                    .map(|r| r.level)
                    .filter(|&l| l > 0)
                    .collect();
                levels.sort_unstable();
                levels.dedup();
                (ctx.version, levels)
            });
    }
}

impl BoundaryHook for FaultState {
    fn before_module(&self, ctx: &CkptContext, next: &'static str) -> bool {
        if self.dead.lock().unwrap().contains(&ctx.rank) {
            self.record_death(ctx);
            return false;
        }
        let planned = {
            let plan = self.plan.lock().unwrap();
            plan.as_ref().map_or(false, |p| {
                p.version == ctx.version
                    && p.module == next
                    && p.victims.contains(&ctx.rank)
            })
        };
        if planned {
            self.dead.lock().unwrap().insert(ctx.rank);
            self.record_death(ctx);
            return false;
        }
        true
    }
}

/// Chunk-counting fault gate: wraps the scheduler's flush gate and, after
/// a configured number of chunks crossed it, marks the victim ranks dead.
/// Flushers polling [`FlushGate::aborted_for`] then abandon the victims'
/// in-flight transfers before the atomic publish — the failure landed
/// mid-transfer-chunk (or mid-aggregation-drain; both paths pace through
/// the same gate).
pub struct FaultGate {
    state: Arc<FaultState>,
    inner: Mutex<Option<Arc<dyn FlushGate>>>,
    /// Chunks remaining until the fault fires; negative = disarmed.
    fuse: AtomicI64,
    fired: AtomicBool,
    victims: Mutex<Vec<usize>>,
}

impl FaultGate {
    pub fn new(state: Arc<FaultState>) -> Arc<Self> {
        Arc::new(FaultGate {
            state,
            inner: Mutex::new(None),
            fuse: AtomicI64::new(-1),
            fired: AtomicBool::new(false),
            victims: Mutex::new(Vec::new()),
        })
    }

    /// Install the wrapped production gate (called from the runtime's
    /// gate-wrapping hook).
    pub fn set_inner(&self, gate: Arc<dyn FlushGate>) {
        *self.inner.lock().unwrap() = Some(gate);
    }

    /// Arm the fuse: the fault lands on the `chunks`-th chunk (1-based)
    /// crossing the gate from now on.
    pub fn arm(&self, chunks: usize, victims: Vec<usize>) {
        assert!(chunks >= 1, "fuse must be at least one chunk");
        *self.victims.lock().unwrap() = victims;
        self.fired.store(false, Ordering::SeqCst);
        self.fuse.store(chunks as i64, Ordering::SeqCst);
    }

    pub fn fired(&self) -> bool {
        self.fired.load(Ordering::SeqCst)
    }
}

impl FlushGate for FaultGate {
    fn before_chunk(&self, bytes: usize) {
        let inner = self.inner.lock().unwrap().clone();
        if let Some(g) = inner {
            g.before_chunk(bytes);
        }
        if self.fuse.load(Ordering::SeqCst) >= 0 && !self.fired.load(Ordering::SeqCst) {
            let prev = self.fuse.fetch_sub(1, Ordering::SeqCst);
            if prev == 1 {
                self.fired.store(true, Ordering::SeqCst);
                let victims = self.victims.lock().unwrap().clone();
                self.state.kill_all(&victims);
            }
        }
    }

    fn aborted_for(&self, rank: usize) -> bool {
        self.fired.load(Ordering::SeqCst)
            && self.victims.lock().unwrap().contains(&rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::Checkpoint;

    fn ctx(rank: usize, version: u64) -> CkptContext {
        let mut c = Checkpoint::new("t", rank, version);
        c.push_region(0, vec![0u8; 64]);
        CkptContext::new("t", rank, 0, version, c)
    }

    #[test]
    fn boundary_plan_kills_only_victims_at_target_version() {
        let st = FaultState::new();
        st.set_plan(BoundaryPlan {
            module: "transfer".to_string(),
            version: 3,
            victims: vec![1],
        });
        assert!(st.before_module(&ctx(0, 3), "transfer"), "non-victim lives");
        assert!(st.before_module(&ctx(1, 2), "transfer"), "other version lives");
        assert!(st.before_module(&ctx(1, 3), "local"), "other module lives");
        assert!(!st.before_module(&ctx(1, 3), "transfer"), "victim dies");
        assert!(st.is_dead(1));
        // Once dead, every later boundary aborts too.
        assert!(!st.before_module(&ctx(1, 3), "version"));
        let (v, levels) = st.death_levels(1).unwrap();
        assert_eq!(v, 3);
        assert!(levels.is_empty(), "no stage recorded anything yet");
    }

    #[test]
    fn death_levels_capture_completed_stages() {
        let st = FaultState::new();
        st.kill_all(&[2]);
        let mut c = ctx(2, 5);
        c.record("local", 1, std::time::Duration::ZERO, 10);
        c.record("partner", 2, std::time::Duration::ZERO, 10);
        assert!(!st.before_module(&c, "erasure"));
        assert_eq!(st.death_levels(2).unwrap(), (5, vec![1, 2]));
    }

    #[test]
    fn fault_gate_fires_on_nth_chunk_and_aborts_victims_only() {
        let st = FaultState::new();
        let gate = FaultGate::new(Arc::clone(&st));
        gate.arm(3, vec![4]);
        gate.before_chunk(1024);
        gate.before_chunk(1024);
        assert!(!gate.fired());
        assert!(!gate.aborted_for(4));
        gate.before_chunk(1024);
        assert!(gate.fired());
        assert!(gate.aborted_for(4));
        assert!(!gate.aborted_for(0), "non-victims keep flushing");
        assert!(st.is_dead(4));
    }

    #[test]
    fn disarmed_gate_never_fires() {
        let gate = FaultGate::new(FaultState::new());
        for _ in 0..100 {
            gate.before_chunk(4096);
        }
        assert!(!gate.fired());
    }
}
