//! Budgeted randomized chaos soak (`veloc soak`).
//!
//! The scenario matrix answers "does every known failure class recover?";
//! the soak answers the question behind ROADMAP item 5's last bullet:
//! *keep* answering it, for hours, across randomized seeds, until the
//! budget runs out. Round 0 always runs the full
//! [`standard_matrix`] at the base seed — every injection point in the
//! catalog (restart-storm and tier-outage included) is covered even under
//! the smallest budget. Every later round re-derives a fresh base seed,
//! shuffles the catalog order, and keeps going until wall-clock budget
//! exhaustion.
//!
//! Failures never stop the soak: each one prints a single line carrying
//! the exact `veloc sim --json '…'` repro (the same one-line-repro
//! contract the matrix runner has), optionally saves its event trace, and
//! the run continues. The final summary serializes to JSON for CI
//! artifact upload.

use crate::sim::runner::{run_scenario_traced, run_scenario_with_obs};
use crate::sim::scenario::{standard_matrix, ScenarioSpec};
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Soak run parameters (the `veloc soak` CLI flags).
#[derive(Clone, Debug)]
pub struct SoakConfig {
    /// Wall-clock budget. Round 0 (the full catalog) always completes,
    /// even if it overruns a tiny budget — coverage beats punctuality.
    pub budget: Duration,
    /// Base seed; every scenario seed derives deterministically from it.
    pub base_seed: u64,
    /// Save the event trace of every failing scenario here.
    pub trace_dir: Option<PathBuf>,
    /// Record a crash-durable flight stream per scenario under this dir.
    /// Dumps are kept only for *failing* scenarios (one subdirectory per
    /// failure, named like the saved trace); passing scenarios delete
    /// theirs so a long soak does not accumulate gigabytes of rings.
    pub flight_dir: Option<PathBuf>,
    /// Run only scenarios whose injection-point name contains this
    /// substring (test hook; `None` = the whole catalog).
    pub filter: Option<String>,
    /// Print per-scenario progress lines, not just failures.
    pub verbose: bool,
}

/// One scenario failure observed during the soak.
#[derive(Clone, Debug)]
pub struct SoakFailure {
    /// The exact failing spec — `spec.repro()` is the one-line repro.
    pub spec: ScenarioSpec,
    /// The scenario error, formatted.
    pub error: String,
    /// Where the event trace was saved, if a trace dir was configured.
    pub trace_path: Option<PathBuf>,
    /// Where the flight dump was kept, if a flight dir was configured.
    pub flight_path: Option<PathBuf>,
}

/// Aggregate outcome of a soak run.
#[derive(Clone, Debug)]
pub struct SoakOutcome {
    /// Catalog rounds started (round 0 is the unshuffled full matrix).
    pub rounds: usize,
    /// Scenarios executed.
    pub runs: usize,
    /// Scenarios that failed (soak continues past failures).
    pub failures: Vec<SoakFailure>,
    /// Runs per injection-point family (name up to the first `:`).
    pub coverage: BTreeMap<String, usize>,
    /// Wall-clock actually spent.
    pub elapsed: Duration,
}

impl SoakOutcome {
    /// Every injection family the catalog declares that this run covered
    /// at least once? (Round 0 guarantees it; the summary asserts it.)
    pub fn full_coverage(&self, catalog: &[ScenarioSpec]) -> bool {
        catalog
            .iter()
            .map(|s| family(&s.inject.name()))
            .all(|f| self.coverage.get(&f).copied().unwrap_or(0) > 0)
    }

    /// Serialize for the CI artifact (`soak-summary.json`).
    pub fn to_json(&self) -> Json {
        let failures: Vec<Json> = self
            .failures
            .iter()
            .map(|f| {
                let j = Json::obj()
                    .set("inject", f.spec.inject.name())
                    .set("repro", f.spec.repro())
                    .set("error", f.error.as_str());
                let j = match &f.trace_path {
                    Some(p) => j.set("trace", p.to_string_lossy().as_ref()),
                    None => j,
                };
                match &f.flight_path {
                    Some(p) => j.set("flight", p.to_string_lossy().as_ref()),
                    None => j,
                }
            })
            .collect();
        let mut cov = Json::obj();
        for (k, v) in &self.coverage {
            cov = cov.set(k, *v);
        }
        Json::obj()
            .set("rounds", self.rounds)
            .set("runs", self.runs)
            .set("failures", Json::Arr(failures))
            .set("coverage", cov)
            .set("elapsed_ms", self.elapsed.as_millis() as u64)
    }
}

fn family(inject_name: &str) -> String {
    inject_name
        .split(':')
        .next()
        .unwrap_or(inject_name)
        .to_string()
}

/// Run the soak. Deterministic given `(base_seed, filter)` up to *which*
/// scenarios fit the budget; every executed scenario is individually
/// reproducible from its printed seed line regardless.
pub fn run_soak(cfg: &SoakConfig) -> SoakOutcome {
    let started = Instant::now();
    let mut rng = Rng::new(cfg.base_seed);
    let mut outcome = SoakOutcome {
        rounds: 0,
        runs: 0,
        failures: Vec::new(),
        coverage: BTreeMap::new(),
        elapsed: Duration::ZERO,
    };
    if let Some(dir) = &cfg.trace_dir {
        let _ = std::fs::create_dir_all(dir);
    }
    if let Some(dir) = &cfg.flight_dir {
        let _ = std::fs::create_dir_all(dir);
    }
    loop {
        let round = outcome.rounds;
        // Round 0: the exact standard matrix, catalog order, base seed —
        // guaranteed full injection coverage. Later rounds: fresh seeds,
        // shuffled order.
        let mut specs = if round == 0 {
            standard_matrix(cfg.base_seed)
        } else {
            let mut s = standard_matrix(rng.next_u64());
            rng.shuffle(&mut s);
            s
        };
        if let Some(f) = &cfg.filter {
            specs.retain(|s| s.inject.name().contains(f.as_str()));
        }
        if specs.is_empty() {
            // A filter that matches nothing: report zero coverage rather
            // than spinning forever.
            break;
        }
        outcome.rounds += 1;
        for spec in &specs {
            // Between scenarios (never mid-scenario), honor the budget —
            // but round 0 always completes for coverage.
            if round > 0 && started.elapsed() >= cfg.budget {
                break;
            }
            let fam = family(&spec.inject.name());
            // Each scenario flies with its own flight-dump directory; the
            // dump is kept only when the scenario fails (CI uploads it),
            // otherwise deleted so long soaks stay disk-bounded.
            let scenario_flight = cfg
                .flight_dir
                .as_ref()
                .map(|dir| dir.join(format!("soak-flight-{}-{}", spec.seed, fam)));
            let (result, trace) = match &scenario_flight {
                Some(fd) => run_scenario_with_obs(spec, None, Some(fd)),
                None => run_scenario_traced(spec),
            };
            outcome.runs += 1;
            *outcome.coverage.entry(fam.clone()).or_insert(0) += 1;
            match result {
                Ok(report) => {
                    if let Some(fd) = &scenario_flight {
                        let _ = std::fs::remove_dir_all(fd);
                    }
                    if cfg.verbose {
                        println!("soak ok   {}", report.summary());
                    }
                }
                Err(e) => {
                    let trace_path = cfg.trace_dir.as_ref().map(|dir| {
                        let p = dir.join(format!("soak-fail-{}-{}.json", spec.seed, fam));
                        let _ = trace.save(spec, &p);
                        p
                    });
                    // The one-line seed repro contract: everything needed
                    // to replay this exact failure, on one line.
                    println!("soak FAIL [{}] {:#} | repro: {}", spec.inject.name(), e, spec.repro());
                    if let Some(fd) = &scenario_flight {
                        println!("soak FAIL flight dump kept: {}", fd.display());
                    }
                    outcome.failures.push(SoakFailure {
                        spec: spec.clone(),
                        error: format!("{e:#}"),
                        trace_path,
                        flight_path: scenario_flight,
                    });
                }
            }
        }
        if started.elapsed() >= cfg.budget {
            break;
        }
    }
    outcome.elapsed = started.elapsed();
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_budget_still_covers_the_full_catalog_once() {
        // Round 0 ignores the budget: every injection family in the
        // catalog must appear in coverage even with budget zero.
        let cfg = SoakConfig {
            budget: Duration::ZERO,
            base_seed: 9000,
            trace_dir: None,
            flight_dir: None,
            filter: None,
            verbose: false,
        };
        let out = run_soak(&cfg);
        assert_eq!(out.rounds, 1, "zero budget = exactly the coverage round");
        let catalog = standard_matrix(9000);
        assert_eq!(out.runs, catalog.len());
        assert!(out.full_coverage(&catalog), "coverage: {:?}", out.coverage);
        assert!(
            out.failures.is_empty(),
            "standard matrix must pass: {:?}",
            out.failures
                .iter()
                .map(|f| f.spec.repro())
                .collect::<Vec<_>>()
        );
        // Summary JSON round-trips through the parser.
        let j = Json::parse(&out.to_json().to_string()).unwrap();
        assert_eq!(j.usize_or("runs", 0), out.runs);
        assert_eq!(j.get("failures").and_then(Json::as_arr).unwrap().len(), 0);
    }

    #[test]
    fn filter_restricts_and_empty_filter_terminates() {
        let cfg = SoakConfig {
            budget: Duration::ZERO,
            base_seed: 41,
            trace_dir: None,
            flight_dir: None,
            filter: Some("after-checkpoint".to_string()),
            verbose: false,
        };
        let out = run_soak(&cfg);
        assert!(out.runs > 0);
        assert!(out.coverage.keys().all(|k| k == "after-checkpoint"));

        let none = run_soak(&SoakConfig {
            filter: Some("no-such-injection".to_string()),
            ..cfg
        });
        assert_eq!(none.runs, 0);
        assert_eq!(none.rounds, 0);
    }

    #[test]
    fn passing_scenarios_delete_their_flight_dumps() {
        let dir = std::env::temp_dir().join("veloc-soak-flight-test");
        let _ = std::fs::remove_dir_all(&dir);
        let out = run_soak(&SoakConfig {
            budget: Duration::ZERO,
            base_seed: 77,
            trace_dir: None,
            flight_dir: Some(dir.clone()),
            filter: Some("after-checkpoint".to_string()),
            verbose: false,
        });
        assert!(out.runs > 0);
        assert!(out.failures.is_empty(), "{:?}", out.failures);
        // Every scenario passed, so every per-scenario dump was deleted:
        // the flight root exists but holds nothing.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .collect();
        assert!(leftovers.is_empty(), "kept dumps for passing runs: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
