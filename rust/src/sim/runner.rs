//! The scenario runner: seeded multi-node application lifetimes end to
//! end — iterate, checkpoint (sync or async engine), land a failure at the
//! configured injection point, restart the survivors, restore, and verify
//! the restored bytes bit-for-bit against shadow copies of the
//! application state.
//!
//! ## Determinism
//!
//! Everything observable is a pure function of the spec:
//! - the workload is the deterministic [`IterativeApp`] with zero compute
//!   budget (no wall-clock dependence),
//! - checkpoint waves hold the async tails behind a backend pause until
//!   every rank's blocking prefix ran, then let them drain FIFO on the
//!   single backend thread — so an injected fault firing inside a tail
//!   can never race another rank's prefix; threads are only used for
//!   sync-engine waves with erasure (which needs concurrent group
//!   members) and no event is recorded from inside them,
//! - trace events are recorded only by this single orchestrator thread,
//!   from settled state (the version registry, wait statuses),
//! - fault hooks mark ranks dead at the injection instant; the storage
//!   wipe itself is always performed here, after the wave settles.
//!
//! ## The `min_level` contract
//!
//! After the failure, the runner computes the *expected* restorable
//! frontier from what each rank had durably completed when the failure
//! landed (registry records, or the death ledger for ranks cut short
//! mid-pipeline) and the failure's blast radius — i.e. a failure is
//! recoverable iff a checkpoint at a sufficient level completed before
//! it. The actual frontier must match exactly (strict scenarios) or reach
//! at least the prediction (the pre-index crash window, where a durable
//! container outlives the completion bookkeeping).

use crate::api::{SimHooks, VelocClient, VelocRuntime};
use crate::app::IterativeApp;
use crate::cluster::{FailureInjector, FailureScope};
use crate::modules::FlushGate;
use crate::pipeline::{BoundaryHook, CkptStatus, EngineMode};
use crate::sim::injection::{BoundaryPlan, FaultGate, FaultState};
use crate::sim::scenario::{ContractMode, InjectionPoint, ScenarioSpec};
use crate::sim::trace::Trace;
use crate::util::json::Json;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Checkpoint name every scenario uses.
pub const SCENARIO_APP: &str = "sim";

/// Outcome of a successful scenario run.
pub struct ScenarioReport {
    pub spec: ScenarioSpec,
    pub scope: FailureScope,
    /// Frontier predicted by the min_level contract model.
    pub expected_frontier: Option<u64>,
    /// Frontier the recovery actually served.
    pub frontier: Option<u64>,
    /// (rank, level) each restore was served from.
    pub restored: Vec<(usize, u8)>,
    /// Ranks whose restored bytes matched the shadow copy bit-for-bit.
    pub verified_ranks: usize,
    pub index_rebuilds: u64,
}

impl ScenarioReport {
    pub fn summary(&self) -> String {
        format!(
            "seed {:>6}  {:<22} scope {:<14} frontier {:?} (expected {:?})  \
             restored {} ranks, verified {}",
            self.spec.seed,
            self.spec.inject.name(),
            scope_str(&self.scope),
            self.frontier,
            self.expected_frontier,
            self.restored.len(),
            self.verified_ranks,
        )
    }
}

/// Everything a run produces besides its trace.
struct RunOutcome {
    scope: FailureScope,
    expected_frontier: Option<u64>,
    frontier: Option<u64>,
    restored: Vec<(usize, u8)>,
    verified_ranks: usize,
    index_rebuilds: u64,
}

/// Run one scenario; any violated invariant returns an error carrying the
/// seed and the one-line repro.
pub fn run_scenario(spec: &ScenarioSpec) -> Result<ScenarioReport> {
    run_scenario_traced(spec).0
}

/// Like [`run_scenario`], but always hands back the event trace — on
/// failure it covers everything up to the violated invariant, which is
/// exactly what gets uploaded as a CI artifact.
pub fn run_scenario_traced(spec: &ScenarioSpec) -> (Result<ScenarioReport>, Trace) {
    run_scenario_with_tracer(spec, None)
}

/// Like [`run_scenario_traced`], with an optional span recorder threaded
/// into the runtime under test. Span timestamps never enter the event
/// trace (replay comparison stays exact); the recorder is exported
/// separately — the CLI attaches it to failing scenarios as a span
/// timeline artifact.
pub fn run_scenario_with_tracer(
    spec: &ScenarioSpec,
    tracer: Option<Arc<crate::obs::TraceRecorder>>,
) -> (Result<ScenarioReport>, Trace) {
    run_scenario_with_obs(spec, tracer, None)
}

/// Like [`run_scenario_with_tracer`], with an optional flight-recorder
/// directory. When set, the run becomes crash-durable: the runner mirrors
/// its trace events into a `sim` flight stream, and the runtime/daemon
/// incarnations under test write their own `runtime`/`daemon` streams
/// into the same directory — `veloc postmortem <dir>` reconstructs the
/// whole cross-process story afterwards. The trace the function returns
/// is identical with or without a flight dir (replay stays exact).
pub fn run_scenario_with_obs(
    spec: &ScenarioSpec,
    tracer: Option<Arc<crate::obs::TraceRecorder>>,
    flight_dir: Option<&Path>,
) -> (Result<ScenarioReport>, Trace) {
    let mut trace = Trace::new();
    if let Some(dir) = flight_dir {
        match crate::obs::FlightRecorder::open(
            dir,
            "sim",
            crate::obs::flight::FLIGHT_MAX_BYTES_DEFAULT,
        ) {
            Ok(f) => trace.set_mirror(f),
            Err(e) => eprintln!("veloc sim: flight stream unavailable: {e:#}"),
        }
    }
    let result = run_inner(spec, &mut trace, tracer, flight_dir)
        .map_err(|e| {
            anyhow!(
                "scenario failed (seed {}): {e:#}\n  repro: {}",
                spec.seed,
                spec.repro()
            )
        })
        .map(|o| ScenarioReport {
            spec: spec.clone(),
            scope: o.scope,
            expected_frontier: o.expected_frontier,
            frontier: o.frontier,
            restored: o.restored,
            verified_ranks: o.verified_ranks,
            index_rebuilds: o.index_rebuilds,
        });
    if let Some(f) = trace.mirror() {
        f.flush();
    }
    (result, trace)
}

/// Re-run the spec embedded in a saved trace and require the replayed
/// event stream to match the recorded one exactly. The diff runs before
/// the scenario's own verdict is reported: a recorded *failure* (the
/// traces CI uploads) replays faithfully when the event streams match,
/// in which case the original failure is returned.
pub fn replay_file(path: &Path) -> Result<ScenarioReport> {
    let (spec, recorded) = Trace::load(path)?;
    let (result, replayed) = run_scenario_traced(&spec);
    if let Some(diff) = recorded.diff(&replayed) {
        bail!(
            "replay diverged from {} — {diff}\n  repro: {}",
            path.display(),
            spec.repro()
        );
    }
    result
}

fn scope_str(scope: &FailureScope) -> String {
    match scope {
        FailureScope::Rank(r) => format!("rank:{r}"),
        FailureScope::Node(n) => format!("node:{n}"),
        FailureScope::MultiNode(ns) => format!(
            "multi-node:{}",
            ns.iter()
                .map(|n| n.to_string())
                .collect::<Vec<_>>()
                .join("+")
        ),
        FailureScope::System => "system".to_string(),
    }
}

fn levels_json(levels: &[u8]) -> Json {
    Json::Arr(levels.iter().map(|&l| Json::Num(l as f64)).collect())
}

fn opt_version_json(v: Option<u64>) -> Json {
    match v {
        Some(v) => Json::from(v),
        None => Json::Null,
    }
}

fn run_inner(
    spec: &ScenarioSpec,
    trace: &mut Trace,
    tracer: Option<Arc<crate::obs::TraceRecorder>>,
    flight_dir: Option<&Path>,
) -> Result<RunOutcome> {
    spec.validate()?;
    // The backend-crash family kills the *daemon*, not ranks: it runs a
    // dedicated two-incarnation lifetime instead of the failure-scope
    // machinery below.
    if matches!(spec.inject, InjectionPoint::BackendCrash) {
        return run_backend_crash(spec, trace, tracer, flight_dir);
    }
    if matches!(spec.inject, InjectionPoint::RestartStorm(_)) {
        return run_restart_storm(spec, trace, tracer, flight_dir);
    }
    let topo = spec.topology();
    let world = topo.world_size();
    let scope = spec.scope.resolve(&topo, spec.seed);
    let injector = FailureInjector::new(topo, 1.0);
    let victims = injector.affected_ranks(&scope);

    // Fault instrumentation: the shared death ledger (boundary hook) and,
    // for chunk-fused injections, the wrapping fault gate.
    let state = FaultState::new();
    let gate = FaultGate::new(Arc::clone(&state));
    let boundary: Arc<dyn BoundaryHook> = Arc::clone(&state);
    let mut hooks = SimHooks {
        wrap_gate: None,
        boundary: Some(boundary),
        fabric: None,
        tracer,
    };
    if matches!(spec.inject, InjectionPoint::MidFlushChunk(_)) {
        let g = Arc::clone(&gate);
        hooks.wrap_gate = Some(Box::new(move |inner| {
            g.set_inner(inner);
            let wrapped: Arc<dyn FlushGate> = g;
            wrapped
        }));
    }
    let mut cfg = spec.to_config();
    if let Some(dir) = flight_dir {
        cfg.obs.flight_dir = Some(dir.to_path_buf());
    }
    let rt = VelocRuntime::new_with_hooks(cfg, hooks)?;

    // Delta GC crash window: armed just before the last wave; fires on
    // every release a victim rank attempts while armed (a dead writer
    // stays dead), killing the victims at the first one.
    let gc_arm = if matches!(spec.inject, InjectionPoint::DeltaGcCrash) {
        let delta = rt
            .delta()
            .ok_or_else(|| anyhow!("delta-gc-crash requires delta"))?;
        let armed = Arc::new(AtomicBool::new(false));
        let armed2 = Arc::clone(&armed);
        let st = Arc::clone(&state);
        let victims2 = victims.clone();
        delta.set_fault_hook(Some(Arc::new(move |point: &str, rank: usize| {
            if point != crate::delta::FAULT_GC_INTENT
                || !armed2.load(Ordering::SeqCst)
                || !victims2.contains(&rank)
            {
                return false;
            }
            st.kill_all(&victims2);
            true
        })));
        Some(armed)
    } else {
        None
    };

    // Pre-index crash window: armed just before the last wave; fires once
    // on the first drain that crosses it and kills the victims.
    let pre_index_arm = if matches!(spec.inject, InjectionPoint::MidDrainPreIndex) {
        let agg = rt
            .aggregator()
            .ok_or_else(|| anyhow!("mid-drain injection requires aggregation"))?;
        let armed = Arc::new(AtomicBool::new(false));
        let fired = Arc::new(AtomicBool::new(false));
        let armed2 = Arc::clone(&armed);
        let st = Arc::clone(&state);
        let victims2 = victims.clone();
        agg.set_fault_hook(Some(Arc::new(move |point: &str| {
            if point != crate::aggregation::FAULT_PRE_INDEX
                || !armed2.load(Ordering::SeqCst)
                || fired.swap(true, Ordering::SeqCst)
            {
                return false;
            }
            st.kill_all(&victims2);
            true
        })));
        Some(armed)
    } else {
        None
    };

    trace.push(
        Json::obj()
            .set("ev", "start")
            // String, not Json::Num: f64-backed numbers round above 2^53.
            .set("seed", spec.seed.to_string())
            .set("world", world)
            .set("scope", scope_str(&scope))
            .set("inject", spec.inject.name()),
    );

    // One client + deterministic app per rank.
    let mut pairs: Vec<(VelocClient, IterativeApp)> = Vec::with_capacity(world);
    for rank in 0..world {
        let client = rt.client(rank);
        let app = IterativeApp::new(
            &client,
            SCENARIO_APP,
            spec.regions,
            spec.region_bytes,
            0.0,
            spec.seed,
        );
        pairs.push((client, app));
    }

    // version -> per-rank shadow copies captured at checkpoint time.
    let mut shadows: BTreeMap<u64, Vec<Vec<Vec<u8>>>> = BTreeMap::new();
    let threaded_waves = spec.engine_mode == EngineMode::Sync && spec.erasure_group >= 2;

    for wave in 1..=spec.waves {
        for (_c, app) in pairs.iter_mut() {
            for _ in 0..spec.steps_per_wave {
                app.step();
            }
        }
        let version = pairs[0].1.iteration;
        // Tier degradation arms before the *penultimate* wave: adaptive
        // placement needs one wave of flushes to observe the slowdown
        // before the final wave can route away from it.
        if wave + 1 == spec.waves {
            if let InjectionPoint::TierDegraded(tier, factor) = &spec.inject {
                let t = rt
                    .env()
                    .fabric
                    .shared_tier(tier)
                    .ok_or_else(|| anyhow!("tier-degraded: unknown tier {tier}"))?;
                t.set_degraded(*factor as f64);
                trace.push(
                    Json::obj()
                        .set("ev", "tier-degraded")
                        .set("tier", tier.as_str())
                        .set("factor", *factor as u64),
                );
            }
        }
        if wave == spec.waves {
            // Arm the injection for the final wave.
            match &spec.inject {
                InjectionPoint::BeforeModule(module) => state.set_plan(BoundaryPlan {
                    module: module.clone(),
                    version,
                    victims: victims.clone(),
                }),
                InjectionPoint::MidFlushChunk(chunks) => {
                    gate.arm(*chunks, victims.clone())
                }
                InjectionPoint::MidDrainPreIndex => {
                    if let Some(armed) = &pre_index_arm {
                        armed.store(true, Ordering::SeqCst);
                    }
                }
                InjectionPoint::DeltaGcCrash => {
                    if let Some(armed) = &gc_arm {
                        armed.store(true, Ordering::SeqCst);
                    }
                }
                InjectionPoint::TierOutage(tier) => {
                    // The shared tier drops off right before the final
                    // wave's flushes: placement must fail them over.
                    let t = rt
                        .env()
                        .fabric
                        .shared_tier(tier)
                        .ok_or_else(|| anyhow!("tier-outage: unknown tier {tier}"))?;
                    t.set_down(true);
                    trace.push(
                        Json::obj()
                            .set("ev", "tier-outage")
                            .set("tier", tier.as_str()),
                    );
                }
                InjectionPoint::AfterCheckpoint
                | InjectionPoint::MidRestart(_)
                | InjectionPoint::DeltaChainBreak(_)
                | InjectionPoint::TierDegraded(_, _) => {}
            }
        }
        shadows.insert(version, pairs.iter().map(|(_, a)| a.snapshot()).collect());

        // Submit the collective wave. Erasure under a sync engine needs
        // concurrent group members; every other shape submits
        // sequentially (async tails settle FIFO on the single backend
        // thread).
        if threaded_waves {
            let results: Vec<Result<()>> = std::thread::scope(|s| {
                let handles: Vec<_> = pairs
                    .iter()
                    .map(|(c, _)| s.spawn(move || c.checkpoint(SCENARIO_APP, version)))
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("rank checkpoint thread"))
                    .collect()
            });
            for r in results {
                r?;
            }
        } else {
            // Barrier: hold the Background-priority async tails until
            // every rank's blocking prefix ran inline, so a chunk-fused
            // fault firing inside an early tail can never race a later
            // rank's prefix — tails then drain FIFO on the single
            // backend thread. (Sync engines run everything inline; the
            // pause is a no-op for them.)
            rt.backend().pause_background(true);
            let submitted: Result<()> = pairs
                .iter()
                .try_for_each(|(c, _)| c.checkpoint(SCENARIO_APP, version));
            rt.backend().pause_background(false);
            submitted?;
        }
        // Settle every rank's pipeline. A timeout here is a scenario bug
        // (the deterministic engine must always settle), so it fails the
        // run instead of being recorded as an ordinary status.
        let mut statuses = Vec::with_capacity(world);
        for (c, _) in &pairs {
            let st = c.checkpoint_wait(SCENARIO_APP, version)?;
            if st == CkptStatus::TimedOut {
                bail!("wave v{version}: rank {} never settled", c.rank());
            }
            statuses.push(st);
        }
        // Record the wave from settled state (registry + statuses).
        let registry = &rt.env().registry;
        let mut ranks = Vec::with_capacity(world);
        for rank in 0..world {
            let levels = registry
                .info(SCENARIO_APP, version, rank)
                .map(|i| i.levels)
                .unwrap_or_default();
            let status = match &statuses[rank] {
                CkptStatus::Done(l) => format!("done:{l}"),
                CkptStatus::Failed(_) => "failed".to_string(),
                CkptStatus::InFlight => "in-flight".to_string(),
                CkptStatus::TimedOut => "timeout".to_string(),
            };
            ranks.push(
                Json::obj()
                    .set("rank", rank)
                    .set("status", status)
                    .set("levels", levels_json(&levels)),
            );
        }
        trace.push(
            Json::obj()
                .set("ev", "wave")
                .set("version", version)
                .set("ranks", Json::Arr(ranks)),
        );
    }
    let last_version = spec.waves * spec.steps_per_wave;

    // Torn mid-chain flush: strip the chunk payloads out of one earlier
    // version's PFS objects (manifest stays durable and CRC-valid), so
    // every newer delta's chain crosses a version whose chunks are gone.
    let mut broken: BTreeSet<u64> = BTreeSet::new();
    if let InjectionPoint::DeltaChainBreak(back) = &spec.inject {
        let target = last_version - (*back as u64) * spec.steps_per_wave;
        let pfs = rt.env().fabric.pfs();
        for rank in 0..world {
            let key = crate::pipeline::storage_key("pfs", SCENARIO_APP, rank, target);
            let Some((bytes, _)) = pfs.get(&key) else {
                bail!("chain-break target {key} missing on the PFS");
            };
            let stripped = crate::delta::strip_payloads(&bytes)?;
            pfs.put(&key, &stripped)?;
        }
        broken.insert(target);
        trace.push(
            Json::obj()
                .set("ev", "chain-break")
                .set("version", target),
        );
    }

    // The failure lands: kill the ranks, wipe the affected failure
    // domains (idempotent for the mid-* points whose victims already
    // died), then flush surviving stragglers.
    rt.inject_failure(&scope);
    trace.push(
        Json::obj()
            .set("ev", "inject")
            .set("point", spec.inject.name())
            .set("scope", scope_str(&scope))
            .set("version", last_version),
    );
    rt.drain();

    // Contract: predict the restorable frontier from what durably
    // completed before the failure, then compare with reality.
    let expected = expected_frontier(spec, &topo, &scope, &rt, &state, world, &broken);
    rt.revive_all();
    let frontier = rt
        .recovery()
        .restorable_frontier(rt.engines(), SCENARIO_APP)?;
    trace.push(
        Json::obj()
            .set("ev", "frontier")
            .set("expected", opt_version_json(expected))
            .set("actual", opt_version_json(frontier))
            .set(
                "mode",
                match spec.contract() {
                    ContractMode::Strict => "strict",
                    ContractMode::AtLeast => "at-least",
                },
            ),
    );
    match spec.contract() {
        ContractMode::Strict => ensure!(
            frontier == expected,
            "min_level contract violated: expected restorable frontier {expected:?}, \
             recovery served {frontier:?}"
        ),
        ContractMode::AtLeast => {
            if let Some(e) = expected {
                let a = frontier.ok_or_else(|| {
                    anyhow!("expected a restorable frontier >= {e}, recovery served none")
                })?;
                ensure!(
                    a >= e,
                    "recovery served frontier {a}, older than the guaranteed {e}"
                );
            }
        }
    }

    // Restore + verify phase.
    let mut restored: Vec<(usize, u8)> = Vec::new();
    let mut verified_ranks = 0usize;
    if let Some(version) = frontier {
        let snaps = shadows
            .get(&version)
            .ok_or_else(|| anyhow!("no shadow copy for restored version {version}"))?;
        match spec.inject {
            InjectionPoint::MidRestart(after) => {
                // Restart storm interrupted by a second blow of the same
                // scope, then completed — restart must be idempotent.
                let mut reinjected = false;
                for rank in 0..world {
                    let level = restore_and_verify(&rt, spec, rank, version, snaps, trace)?;
                    restored.push((rank, level));
                    verified_ranks += 1;
                    // validate() bounds `after` to 1..=world, so the
                    // second blow always fires within this loop.
                    if !reinjected && rank + 1 >= after {
                        rt.inject_failure(&scope);
                        rt.revive_all();
                        reinjected = true;
                        trace.push(
                            Json::obj()
                                .set("ev", "reinject")
                                .set("scope", scope_str(&scope))
                                .set("after_ranks", rank + 1),
                        );
                    }
                }
                // Every affected rank died again mid-restart: restore
                // them once more and re-verify.
                for &rank in &victims {
                    restore_and_verify(&rt, spec, rank, version, snaps, trace)?;
                    verified_ranks += 1;
                }
            }
            _ => {
                for rank in 0..world {
                    let level = restore_and_verify(&rt, spec, rank, version, snaps, trace)?;
                    restored.push((rank, level));
                    verified_ranks += 1;
                }
            }
        }
    } else {
        ensure!(
            expected.is_none(),
            "recovery served no version although {expected:?} was expected"
        );
    }

    // GC-crash scenarios: the interrupted collection must have been
    // finished by the refcount-ledger replay, the previous retained
    // version must still restore bit-for-bit, and no live manifest may
    // reference a chunk the replayed GC freed.
    if matches!(spec.inject, InjectionPoint::DeltaGcCrash) {
        let replays = rt.metrics().counter("delta.gc.replays");
        ensure!(
            replays >= 1,
            "gc crash left no ledger replay (counter {replays})"
        );
        let prev = last_version - spec.steps_per_wave;
        if let Some(snaps) = shadows.get(&prev) {
            for rank in 0..world {
                restore_and_verify(&rt, spec, rank, prev, snaps, trace)?;
                verified_ranks += 1;
            }
        }
        let delta = rt.delta().ok_or_else(|| anyhow!("delta state missing"))?;
        for rank in 0..world {
            let node = topo.node_of(rank);
            for m in delta.manifests_of(SCENARIO_APP, rank) {
                for fp in m.fp_set() {
                    ensure!(
                        delta.store(node).contains(&fp),
                        "rank {rank} v{} references chunk {} missing from \
                         the node {node} store after the GC replay",
                        m.version,
                        fp.hex()
                    );
                }
            }
        }
    }

    // Tier-injection scenarios additionally assert the placement engine
    // did what the checkpoint outcome depends on: outages produce real
    // failovers, degradations produce real re-routing. (Both scenarios
    // already proved bit-for-bit restores above — these checks pin the
    // mechanism, not just the outcome.)
    match &spec.inject {
        InjectionPoint::TierOutage(tier) => {
            let failovers = rt.metrics().counter("placement.failovers");
            ensure!(
                failovers >= 1,
                "tier {tier} outage produced no placement failover"
            );
            let routed_down = rt
                .metrics()
                .counter(&format!("placement.routed.puts.{tier}"));
            let total: u64 = rt
                .placement()
                .map(|p| p.health_all().iter().map(|h| h.routed_puts).sum::<u64>())
                .unwrap_or(0);
            ensure!(
                total > routed_down,
                "every flush still claims the down tier {tier}"
            );
        }
        InjectionPoint::TierDegraded(tier, _) => {
            let fallback = if tier == "pfs" { "burst-buffer" } else { "pfs" };
            let routed = rt
                .metrics()
                .counter(&format!("placement.routed.puts.{fallback}"));
            ensure!(
                routed >= world as u64,
                "adaptive placement never routed the final wave off the \
                 degraded tier {tier} (fallback {fallback} served {routed} puts)"
            );
        }
        _ => {}
    }

    let index_rebuilds = rt.metrics().counter("agg.index.rebuilds");
    if matches!(spec.inject, InjectionPoint::MidDrainPreIndex) && frontier == Some(last_version)
    {
        // The final wave's group-0 container was never indexed; serving
        // it proves the header rebuild ran.
        ensure!(
            index_rebuilds >= 1,
            "durable-but-unindexed container restored without an index rebuild"
        );
    }

    trace.push(
        Json::obj()
            .set("ev", "end")
            .set("ok", true)
            .set("verified", verified_ranks),
    );
    Ok(RunOutcome {
        scope,
        expected_frontier: expected,
        frontier,
        restored,
        verified_ranks,
        index_rebuilds,
    })
}

/// Job id the backend-crash scenarios register with the daemon.
const SCENARIO_JOB: &str = "sim";

/// Uniquifies the per-run daemon home directories (matrix runs many
/// backend scenarios inside one process).
static BACKEND_DIRS: AtomicU64 = AtomicU64::new(0);

/// The backend-crash lifetime: one daemon incarnation serves every wave
/// and dies mid-drain *after acking* the final wave (payloads journaled
/// and fsynced, async flushes parked); a second incarnation over the same
/// storage replays the WAL. The contract is the paper's durability claim:
/// every acked version settles after the restart and restores
/// bit-for-bit — including the wave whose flushes the crash swallowed.
fn run_backend_crash(
    spec: &ScenarioSpec,
    trace: &mut Trace,
    tracer: Option<Arc<crate::obs::TraceRecorder>>,
    flight_dir: Option<&Path>,
) -> Result<RunOutcome> {
    use crate::backend::{scoped_name, BackendDaemon};

    let topo = spec.topology();
    let world = topo.world_size();
    let scope = spec.scope.resolve(&topo, spec.seed); // pinned rank 0; unused
    let wait_t = Duration::from_secs(30);

    let mut cfg = spec.to_config();
    if let Some(d) = flight_dir {
        cfg.obs.flight_dir = Some(d.to_path_buf());
    }
    let dir = std::env::temp_dir().join(format!(
        "veloc-sim-backend-{}-{}-{}",
        spec.seed,
        std::process::id(),
        BACKEND_DIRS.fetch_add(1, Ordering::SeqCst)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    cfg.backend.dir = dir.clone();
    // The scenario exercises the journal, not admission control: size the
    // window so no wave is pushed back.
    cfg.backend.queue_depth = world * (spec.waves as usize) + 8;
    // Storage outlives the daemon (node-local tiers and the PFS are not
    // the daemon's memory): both incarnations share one fabric.
    let fabric = Arc::new(crate::storage::StorageFabric::build(&cfg.fabric)?);

    trace.push(
        Json::obj()
            .set("ev", "start")
            .set("seed", spec.seed.to_string())
            .set("world", world)
            .set("scope", scope_str(&scope))
            .set("inject", spec.inject.name()),
    );

    // Incarnation 1: serve every wave; hold the final wave's drains.
    let daemon = BackendDaemon::start_with_hooks(
        cfg.clone(),
        SimHooks {
            wrap_gate: None,
            boundary: None,
            fabric: Some(Arc::clone(&fabric)),
            tracer: tracer.clone(),
        },
    )?;
    let mut pairs: Vec<(VelocClient, IterativeApp)> = Vec::with_capacity(world);
    for rank in 0..world {
        let client = daemon.client(SCENARIO_JOB, rank, wait_t)?;
        let app = IterativeApp::new(
            &client,
            SCENARIO_APP,
            spec.regions,
            spec.region_bytes,
            0.0,
            spec.seed,
        );
        pairs.push((client, app));
    }
    let mut shadows: BTreeMap<u64, Vec<Vec<Vec<u8>>>> = BTreeMap::new();
    for wave in 1..=spec.waves {
        for (_c, app) in pairs.iter_mut() {
            for _ in 0..spec.steps_per_wave {
                app.step();
            }
        }
        let version = pairs[0].1.iteration;
        shadows.insert(version, pairs.iter().map(|(_, a)| a.snapshot()).collect());
        if wave == spec.waves {
            // Quiesce first (all earlier journal entries settled) so the
            // pending set at crash time is exactly the final wave — the
            // replay count in the trace stays deterministic. Then park
            // the async tails: the final wave is acked and journaled but
            // never settles inside this incarnation.
            ensure!(
                daemon.drain(Duration::from_secs(30)),
                "waves before the crash never settled"
            );
            daemon.runtime().backend().pause_background(true);
        }
        for (c, _) in &pairs {
            c.checkpoint(SCENARIO_APP, version)?;
        }
        if wave < spec.waves {
            let mut ranks = Vec::with_capacity(world);
            for (c, _) in &pairs {
                let st = c.checkpoint_wait(SCENARIO_APP, version)?;
                let s = match st {
                    CkptStatus::Done(l) => format!("done:{l}"),
                    other => bail!(
                        "wave v{version}: rank {} did not settle: {other:?}",
                        c.rank()
                    ),
                };
                ranks.push(Json::obj().set("rank", c.rank()).set("status", s));
            }
            trace.push(
                Json::obj()
                    .set("ev", "wave")
                    .set("version", version)
                    .set("ranks", Json::Arr(ranks)),
            );
        } else {
            // Every ack implies a durable journal record; wait until the
            // dispatcher has also run the blocking prefixes so the crash
            // lands mid-drain, not mid-queue.
            ensure!(
                daemon.wait_dispatched(Duration::from_secs(30)),
                "final wave was never dispatched"
            );
            trace.push(
                Json::obj()
                    .set("ev", "wave")
                    .set("version", version)
                    .set("acked", world),
            );
        }
    }
    let last_version = spec.waves * spec.steps_per_wave;

    // The daemon dies mid-drain: queued work is dropped, in-flight tails
    // are killed, nothing settles. Storage and the journal survive.
    daemon.crash();
    trace.push(
        Json::obj()
            .set("ev", "inject")
            .set("point", spec.inject.name())
            .set("scope", scope_str(&scope))
            .set("version", last_version),
    );
    drop(pairs);
    drop(daemon);

    // Incarnation 2: replay the journal over the surviving storage.
    let daemon2 = BackendDaemon::start_with_hooks(
        cfg,
        SimHooks {
            wrap_gate: None,
            boundary: None,
            fabric: Some(Arc::clone(&fabric)),
            tracer: tracer.clone(),
        },
    )?;
    let replayed = daemon2
        .runtime()
        .metrics()
        .counter("backend.journal.replayed");
    ensure!(
        replayed == world as u64,
        "journal replay resumed {replayed} checkpoints, expected exactly {world} \
         (one acked-but-unsettled per rank)"
    );
    ensure!(
        daemon2.drain(Duration::from_secs(60)),
        "replayed checkpoints never settled"
    );
    trace.push(
        Json::obj()
            .set("ev", "backend-replay")
            .set("replayed", replayed),
    );

    // Every acked command of the swallowed wave must now be settled.
    for rank in 0..world {
        let client = daemon2.client(SCENARIO_JOB, rank, wait_t)?;
        let st = client.checkpoint_wait(SCENARIO_APP, last_version)?;
        ensure!(
            matches!(st, CkptStatus::Done(_)),
            "rank {rank}: replayed v{last_version} settled as {st:?}"
        );
    }

    // The restorable frontier must reach the acked final wave exactly.
    let scoped = scoped_name(SCENARIO_JOB, SCENARIO_APP);
    let expected = Some(last_version);
    let frontier = daemon2
        .runtime()
        .recovery()
        .restorable_frontier(daemon2.runtime().engines(), &scoped)?;
    trace.push(
        Json::obj()
            .set("ev", "frontier")
            .set("expected", opt_version_json(expected))
            .set("actual", opt_version_json(frontier))
            .set("mode", "strict"),
    );
    ensure!(
        frontier == expected,
        "min_level contract violated: expected restorable frontier {expected:?}, \
         recovery served {frontier:?}"
    );

    // And *every* acked version — settled before or replayed after the
    // crash — restores bit-for-bit against its shadow copy.
    let mut restored: Vec<(usize, u8)> = Vec::new();
    let mut verified_ranks = 0usize;
    for (&version, snaps) in shadows.iter() {
        for rank in 0..world {
            let client = daemon2.client(SCENARIO_JOB, rank, wait_t)?;
            let app = IterativeApp::new(
                &client,
                SCENARIO_APP,
                spec.regions,
                spec.region_bytes,
                0.0,
                spec.seed,
            );
            let info = client.restart_version(SCENARIO_APP, version)?.ok_or_else(|| {
                anyhow!("rank {rank}: restore of acked v{version} failed after the daemon restart")
            })?;
            ensure!(
                info.version == version,
                "rank {rank}: asked for v{version}, restored v{}",
                info.version
            );
            let diff = app.diff_snapshot(&snaps[rank]);
            ensure!(
                diff.is_empty(),
                "rank {rank}: restored v{version} differs from the shadow copy in regions {diff:?}"
            );
            trace.push(
                Json::obj()
                    .set("ev", "restore")
                    .set("rank", rank)
                    .set("version", version)
                    .set("level", info.level as u64)
                    .set("crc", app.state_digest() as u64),
            );
            if version == last_version {
                restored.push((rank, info.level));
            }
            verified_ranks += 1;
        }
    }
    let index_rebuilds = daemon2.runtime().metrics().counter("agg.index.rebuilds");
    trace.push(
        Json::obj()
            .set("ev", "end")
            .set("ok", true)
            .set("verified", verified_ranks),
    );
    drop(daemon2);
    let _ = std::fs::remove_dir_all(&dir);
    Ok(RunOutcome {
        scope,
        expected_frontier: expected,
        frontier,
        restored,
        verified_ranks,
        index_rebuilds,
    })
}

/// The restart-storm lifetime: after every checkpoint wave settles, N
/// restart clients cold-restore the final wave through the daemon —
/// hammering a small set of ranks so the restore plane's read-through
/// cache and single-flight table carry the load. Mid-storm the daemon is
/// killed and restarted over the surviving storage; the remaining clients
/// finish against the fresh incarnation (whose cache starts cold). Every
/// client must restore bit-for-bit, and a deliberately poisoned cache
/// entry must trip the fingerprint check and be refetched, never served.
fn run_restart_storm(
    spec: &ScenarioSpec,
    trace: &mut Trace,
    tracer: Option<Arc<crate::obs::TraceRecorder>>,
    flight_dir: Option<&Path>,
) -> Result<RunOutcome> {
    use crate::backend::{scoped_name, BackendDaemon};

    let InjectionPoint::RestartStorm(clients) = &spec.inject else {
        bail!("run_restart_storm dispatched on {:?}", spec.inject);
    };
    let clients = *clients;
    let topo = spec.topology();
    let world = topo.world_size();
    let scope = spec.scope.resolve(&topo, spec.seed); // pinned rank 0; unused
    let wait_t = Duration::from_secs(30);

    let mut cfg = spec.to_config();
    cfg.restore.enabled = true; // the storm exercises the serving plane
    if let Some(d) = flight_dir {
        cfg.obs.flight_dir = Some(d.to_path_buf());
    }
    let dir = std::env::temp_dir().join(format!(
        "veloc-sim-storm-{}-{}-{}",
        spec.seed,
        std::process::id(),
        BACKEND_DIRS.fetch_add(1, Ordering::SeqCst)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    cfg.backend.dir = dir.clone();
    cfg.backend.queue_depth = world * (spec.waves as usize) + 8;
    // Storage outlives the daemon: both incarnations share one fabric.
    let fabric = Arc::new(crate::storage::StorageFabric::build(&cfg.fabric)?);

    trace.push(
        Json::obj()
            .set("ev", "start")
            .set("seed", spec.seed.to_string())
            .set("world", world)
            .set("scope", scope_str(&scope))
            .set("inject", spec.inject.name()),
    );

    // Incarnation 1: serve every wave to full settlement.
    let daemon = BackendDaemon::start_with_hooks(
        cfg.clone(),
        SimHooks {
            wrap_gate: None,
            boundary: None,
            fabric: Some(Arc::clone(&fabric)),
            tracer: tracer.clone(),
        },
    )?;
    let mut pairs: Vec<(VelocClient, IterativeApp)> = Vec::with_capacity(world);
    for rank in 0..world {
        let client = daemon.client(SCENARIO_JOB, rank, wait_t)?;
        let app = IterativeApp::new(
            &client,
            SCENARIO_APP,
            spec.regions,
            spec.region_bytes,
            0.0,
            spec.seed,
        );
        pairs.push((client, app));
    }
    let mut snaps: Vec<Vec<Vec<u8>>> = Vec::new();
    for _wave in 1..=spec.waves {
        for (_c, app) in pairs.iter_mut() {
            for _ in 0..spec.steps_per_wave {
                app.step();
            }
        }
        let version = pairs[0].1.iteration;
        snaps = pairs.iter().map(|(_, a)| a.snapshot()).collect();
        for (c, _) in &pairs {
            c.checkpoint(SCENARIO_APP, version)?;
        }
        for (c, _) in &pairs {
            let st = c.checkpoint_wait(SCENARIO_APP, version)?;
            ensure!(
                matches!(st, CkptStatus::Done(_)),
                "wave v{version}: rank {} did not settle: {st:?}",
                c.rank()
            );
        }
        trace.push(Json::obj().set("ev", "wave").set("version", version));
    }
    let last_version = spec.waves * spec.steps_per_wave;
    ensure!(
        daemon.drain(Duration::from_secs(30)),
        "checkpoint waves never settled before the storm"
    );
    drop(pairs);

    // The storm hammers two ranks (client i -> rank i % 2): past the
    // first touch of each rank, every restore must be a cache hit.
    let storm_rank = |i: usize| i % 2;
    let mut restored: Vec<(usize, u8)> = Vec::new();
    let mut verified_ranks = 0usize;
    let storm_one = |daemon: &BackendDaemon, i: usize| -> Result<u8> {
        let rank = storm_rank(i);
        let client = daemon.client(SCENARIO_JOB, rank, wait_t)?;
        let app = IterativeApp::new(
            &client,
            SCENARIO_APP,
            spec.regions,
            spec.region_bytes,
            0.0,
            spec.seed,
        );
        let info = client
            .restart_version(SCENARIO_APP, last_version)?
            .ok_or_else(|| anyhow!("storm client {i}: restore of v{last_version} failed"))?;
        ensure!(
            info.version == last_version,
            "storm client {i}: asked for v{last_version}, restored v{}",
            info.version
        );
        let diff = app.diff_snapshot(&snaps[rank]);
        ensure!(
            diff.is_empty(),
            "storm client {i}: restored v{last_version} differs from the shadow \
             copy of rank {rank} in regions {diff:?}"
        );
        Ok(info.level)
    };

    // First half of the storm against incarnation 1.
    let half = clients / 2;
    for i in 0..half {
        let level = storm_one(&daemon, i)?;
        restored.push((storm_rank(i), level));
        verified_ranks += 1;
        trace.push(
            Json::obj()
                .set("ev", "storm-restore")
                .set("client", i)
                .set("rank", storm_rank(i))
                .set("level", level as u64),
        );
    }
    // Sequential restores over two ranks: everything past the two first
    // touches must have been served out of the read-through cache.
    let hits1 = daemon.runtime().metrics().counter("restore.cache.hits");
    ensure!(
        hits1 >= half.saturating_sub(2) as u64,
        "first storm half: {hits1} cache hits over {half} restores of 2 ranks"
    );

    // The daemon dies mid-storm; storage survives.
    daemon.crash();
    trace.push(
        Json::obj()
            .set("ev", "inject")
            .set("point", spec.inject.name())
            .set("scope", scope_str(&scope))
            .set("version", last_version),
    );
    drop(daemon);

    // Incarnation 2: a fresh daemon (cold cache) over the same storage
    // serves the rest of the storm.
    let daemon2 = BackendDaemon::start_with_hooks(
        cfg,
        SimHooks {
            wrap_gate: None,
            boundary: None,
            fabric: Some(Arc::clone(&fabric)),
            tracer: tracer.clone(),
        },
    )?;
    for i in half..clients {
        let level = storm_one(&daemon2, i)?;
        restored.push((storm_rank(i), level));
        verified_ranks += 1;
        trace.push(
            Json::obj()
                .set("ev", "storm-restore")
                .set("client", i)
                .set("rank", storm_rank(i))
                .set("level", level as u64),
        );
    }

    // Poison the cached container the last storm client just pulled in:
    // the fingerprint check must catch it and the refetch must still
    // serve correct bytes — corrupt cache memory is never trusted.
    let eng = daemon2
        .runtime()
        .restore_engine()
        .ok_or_else(|| anyhow!("restore plane disabled under a restart-storm scenario"))?
        .clone();
    let scoped = scoped_name(SCENARIO_JOB, SCENARIO_APP);
    let poison_rank = storm_rank(clients - 1);
    ensure!(
        eng.poison("local", &scoped, poison_rank, last_version),
        "rank {poison_rank} v{last_version} was not resident in the cache"
    );
    let level = storm_one(&daemon2, poison_rank)?;
    verified_ranks += 1;
    let poisoned = daemon2
        .runtime()
        .metrics()
        .counter("restore.cache.poisoned");
    ensure!(
        poisoned >= 1,
        "poisoned cache entry served without tripping the fingerprint check"
    );
    trace.push(
        Json::obj()
            .set("ev", "poison-refetch")
            .set("rank", poison_rank)
            .set("level", level as u64)
            .set("poisoned", poisoned),
    );

    // The frontier contract holds across the mid-storm restart.
    let scoped_app = scoped;
    let expected = Some(last_version);
    let frontier = daemon2
        .runtime()
        .recovery()
        .restorable_frontier(daemon2.runtime().engines(), &scoped_app)?;
    trace.push(
        Json::obj()
            .set("ev", "frontier")
            .set("expected", opt_version_json(expected))
            .set("actual", opt_version_json(frontier))
            .set("mode", "strict"),
    );
    ensure!(
        frontier == expected,
        "min_level contract violated: expected restorable frontier {expected:?}, \
         recovery served {frontier:?}"
    );

    let index_rebuilds = daemon2.runtime().metrics().counter("agg.index.rebuilds");
    trace.push(
        Json::obj()
            .set("ev", "end")
            .set("ok", true)
            .set("verified", verified_ranks),
    );
    drop(daemon2);
    let _ = std::fs::remove_dir_all(&dir);
    Ok(RunOutcome {
        scope,
        expected_frontier: expected,
        frontier,
        restored,
        verified_ranks,
        index_rebuilds,
    })
}

/// Restore one rank into a fresh client + app (fresh-process semantics)
/// and verify the restored bytes bit-for-bit against the shadow copy.
/// Returns the level that served the restore.
fn restore_and_verify(
    rt: &Arc<VelocRuntime>,
    spec: &ScenarioSpec,
    rank: usize,
    version: u64,
    snaps: &[Vec<Vec<u8>>],
    trace: &mut Trace,
) -> Result<u8> {
    let client = rt.client(rank);
    let app = IterativeApp::new(
        &client,
        SCENARIO_APP,
        spec.regions,
        spec.region_bytes,
        0.0,
        spec.seed,
    );
    let info = client
        .restart_version(SCENARIO_APP, version)?
        .ok_or_else(|| anyhow!("rank {rank}: restore of frontier v{version} failed"))?;
    ensure!(
        info.version == version,
        "rank {rank}: asked for v{version}, restored v{}",
        info.version
    );
    let diff = app.diff_snapshot(&snaps[rank]);
    ensure!(
        diff.is_empty(),
        "rank {rank}: restored v{version} differs from the shadow copy in regions {diff:?}"
    );
    trace.push(
        Json::obj()
            .set("ev", "restore")
            .set("rank", rank)
            .set("version", version)
            .set("level", info.level as u64)
            .set("crc", app.state_digest() as u64),
    );
    Ok(info.level)
}

/// Predict the newest version every rank can still restore, given the
/// failure's blast radius and what each rank durably completed before it
/// died (registry records, or the death ledger for pipelines cut short).
/// Under delta, remote levels serve a version only if the *whole manifest
/// chain* is durable at that level (and, for the PFS, not torn by a
/// chain break); node-local restores need only the target's thin
/// container because the surviving chunk store covers the ancestors.
fn expected_frontier(
    spec: &ScenarioSpec,
    topo: &crate::cluster::Topology,
    scope: &FailureScope,
    rt: &Arc<VelocRuntime>,
    state: &Arc<FaultState>,
    world: usize,
    broken: &BTreeSet<u64>,
) -> Option<u64> {
    let injector = FailureInjector::new(*topo, 1.0);
    let wiped: BTreeSet<usize> = injector.affected_nodes(scope).into_iter().collect();
    let system = matches!(scope, FailureScope::System);
    let registry = &rt.env().registry;
    let node_ok = |n: usize| !system && !wiped.contains(&n);
    let levels_of = |rank: usize, version: u64| -> Vec<u8> {
        if let Some((v, levels)) = state.death_levels(rank) {
            if v == version {
                return levels;
            }
        }
        registry
            .info(SCENARIO_APP, version, rank)
            .map(|i| i.levels)
            .unwrap_or_default()
    };
    'versions: for version in registry.versions(SCENARIO_APP) {
        let chain: Vec<u64> = if spec.delta {
            spec.delta_chain_versions(version)
        } else {
            vec![version]
        };
        for rank in 0..world {
            let levels = levels_of(rank, version);
            // Level 1: the rank's own node-local copy.
            let mut ok = levels.contains(&1) && node_ok(topo.node_of(rank));
            // Level 2: my copy on my partner's node (delta: the chain of
            // partner copies lives on the same node).
            if !ok && spec.with_partner {
                let pnode = topo.node_of(topo.partner_of(rank));
                ok = pnode != topo.node_of(rank)
                    && node_ok(pnode)
                    && chain.iter().all(|&w| levels_of(rank, w).contains(&2));
            }
            // Level 3: rebuilt from every *other* group member's local
            // copy + parity (the rank's own parity is not needed).
            if !ok && spec.erasure_group >= 2 && topo.nodes % spec.erasure_group == 0 {
                let group = topo.erasure_group(rank, spec.erasure_group);
                ok = group.iter().filter(|&&m| m != rank).all(|&m| {
                    let lm = levels_of(m, version);
                    node_ok(topo.node_of(m)) && lm.contains(&1) && lm.contains(&3)
                });
            }
            // Level 4: the PFS survives everything the matrix throws —
            // but a delta restore needs the whole chain flushed and
            // untorn.
            if !ok {
                ok = chain.iter().all(|&w| {
                    levels_of(rank, w).contains(&4) && !broken.contains(&w)
                });
            }
            if !ok {
                continue 'versions;
            }
        }
        return Some(version);
    }
    None
}
