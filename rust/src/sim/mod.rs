//! Deterministic crash–recover–verify scenario engine with trace replay.
//!
//! The testing backbone for the multi-level pipeline's core claim: that
//! the local → partner/XOR → erasure → PFS hierarchy survives the
//! realistic failure mix. A scenario runs a seeded multi-node application
//! lifetime end to end — iterate → checkpoint (sync or async engine) →
//! land a [`cluster::FailureScope`](crate::cluster::FailureScope) at an
//! arbitrary *injection point* (between pipeline modules, mid-transfer
//! chunk through a fault-injecting flush gate, mid-aggregation-drain, in
//! the pre-index crash window, mid-restart, a torn mid-chain delta flush,
//! a delta-GC writer crash in the post-intent window, or a death of the
//! active-backend daemon itself mid-drain with the final wave acked) →
//! restart survivors → restore → verify restored bytes bit-for-bit
//! against shadow copies.
//!
//! - [`scenario`] — specs: seed + cluster shape + stack permutation +
//!   scope + injection point, one line of JSON each, plus the standard
//!   sweep matrix asserting the `FailureScope::min_level` contract.
//! - [`injection`] — the death ledger ([`FaultState`], a
//!   [`BoundaryHook`](crate::pipeline::BoundaryHook)) and the
//!   chunk-counting [`FaultGate`].
//! - [`trace`] — structured event traces; saved traces replay exactly
//!   from their embedded spec.
//! - [`runner`] — the orchestrator; every failing exploration shrinks to
//!   the one-line repro `veloc sim --json '<spec>'`.
//! - [`corrupt`] — the seeded byte-mutation engine behind the hostile
//!   corruption suite (`rust/tests/hostile.rs`) and the fuzz corpus.
//! - [`soak`] — the budgeted randomized chaos runner (`veloc soak`):
//!   round 0 covers the whole injection catalog, then randomized rounds
//!   until the wall-clock budget is spent, one-line seed repro per
//!   failure.

pub mod corrupt;
pub mod injection;
pub mod runner;
pub mod scenario;
pub mod soak;
pub mod trace;

pub use corrupt::{mutate, refresh_crc32_trailer, Mutation};
pub use injection::{BoundaryPlan, FaultGate, FaultState};
pub use soak::{run_soak, SoakConfig, SoakFailure, SoakOutcome};
pub use runner::{
    replay_file, run_scenario, run_scenario_traced, run_scenario_with_obs,
    run_scenario_with_tracer, ScenarioReport, SCENARIO_APP,
};
pub use scenario::{
    base_spec, standard_matrix, ContractMode, InjectionPoint, ScenarioSpec, ScopeKind,
    ScopeSpec, DELTA_MAX_CHAIN,
};
pub use trace::Trace;
