//! Scenario specifications: seed + cluster shape + module-stack
//! permutation + failure scope + injection point. A spec serializes to one
//! line of JSON, so any failing exploration reproduces exactly with
//! `veloc sim --json '<spec>'` (the repro line every failure prints).

use crate::api::VelocConfig;
use crate::cluster::{FailureScope, Topology};
use crate::modules::TierPolicy;
use crate::pipeline::EngineMode;
use crate::scheduler::SchedulerPolicy;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};
use std::time::Duration;

/// Failure-scope family; the concrete target is either pinned or derived
/// deterministically from the scenario seed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScopeKind {
    Rank,
    Node,
    MultiNode,
    System,
}

impl ScopeKind {
    pub fn name(&self) -> &'static str {
        match self {
            ScopeKind::Rank => "rank",
            ScopeKind::Node => "node",
            ScopeKind::MultiNode => "multi-node",
            ScopeKind::System => "system",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "rank" => Ok(ScopeKind::Rank),
            "node" => Ok(ScopeKind::Node),
            "multi-node" => Ok(ScopeKind::MultiNode),
            "system" => Ok(ScopeKind::System),
            other => bail!("scope must be rank|node|multi-node|system, got {other}"),
        }
    }
}

/// Scope family plus an optional pinned target (rank id for `Rank`, first
/// node id otherwise; `MultiNode` takes the pinned node and its ring
/// neighbour — exactly the partner-pair-killing pattern).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScopeSpec {
    pub kind: ScopeKind,
    pub target: Option<usize>,
}

impl ScopeSpec {
    /// Materialize the concrete scope; unpinned targets derive from the
    /// seed, so the same spec always kills the same ranks.
    pub fn resolve(&self, topo: &Topology, seed: u64) -> FailureScope {
        let mut rng = Rng::new(seed ^ 0x5C0_9E5C);
        match self.kind {
            ScopeKind::Rank => {
                let r = match self.target {
                    Some(t) => t,
                    None => rng.range_usize(0, topo.world_size()),
                };
                FailureScope::Rank(r)
            }
            ScopeKind::Node => {
                let n = match self.target {
                    Some(t) => t,
                    None => rng.range_usize(0, topo.nodes),
                };
                FailureScope::Node(n)
            }
            ScopeKind::MultiNode => {
                let n = match self.target {
                    Some(t) => t,
                    None => rng.range_usize(0, topo.nodes),
                };
                FailureScope::MultiNode(vec![n, (n + 1) % topo.nodes])
            }
            ScopeKind::System => FailureScope::System,
        }
    }

    fn to_json(self) -> Json {
        let j = Json::obj().set("kind", self.kind.name());
        match self.target {
            Some(t) => j.set("target", t),
            None => j,
        }
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(ScopeSpec {
            kind: ScopeKind::parse(j.str_or("kind", "node"))?,
            target: j.get("target").and_then(Json::as_usize),
        })
    }
}

/// Checkpoints per delta chain in delta scenarios (a full every 3rd):
/// shared between `ScenarioSpec::to_config` and the runner's chain-aware
/// contract model, so prediction and behaviour derive from one constant.
pub const DELTA_MAX_CHAIN: u64 = 3;

/// Where in the checkpoint/restart lifetime the failure lands.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum InjectionPoint {
    /// After the final checkpoint wave fully settled (the baseline the
    /// `min_level` contract is exact for).
    AfterCheckpoint,
    /// Victim ranks die right before the named module runs — the failure
    /// lands between pipeline stages, mid-module-stack.
    BeforeModule(String),
    /// The failure lands on the N-th flush chunk crossing the scheduler
    /// gate — mid-transfer-chunk for the direct PFS path, mid-drain for
    /// the aggregated path (both pace through the same gate).
    MidFlushChunk(usize),
    /// The aggregation writer dies between container publish and index
    /// persist; recovery must rebuild the index from container headers.
    MidDrainPreIndex,
    /// The failure repeats mid-restart: after N ranks restored, the same
    /// scope fires again and the restart must complete idempotently.
    MidRestart(usize),
    /// Delta: a mid-chain flush is torn — the PFS object `back`
    /// checkpoints before the last keeps its manifest but loses its chunk
    /// payloads, then the node failure wipes the victims' local copies.
    /// Recovery must refuse every version whose chain crosses the break
    /// and fall back to the newest version with an intact chain (at worst
    /// the last forced full).
    DeltaChainBreak(usize),
    /// Delta: a victim rank dies inside version GC after persisting the
    /// chunk store's decref intent but before applying it — the refcount
    /// ledger replay must finish the GC exactly once and leave every
    /// retained version restorable.
    DeltaGcCrash,
    /// The named shared tier goes offline right before the final
    /// checkpoint wave: placement must fail the level-4 flushes over to
    /// the next-best tier, and — after the node failure lands — restores
    /// must locate the checkpoints wherever they landed. The tier stays
    /// down through the restore (an outage is not fixed by restarting).
    TierOutage(String),
    /// The named shared tier degrades (modeled service times multiplied)
    /// right before the *penultimate* wave: adaptive placement observes
    /// the slowdown and routes the final wave's flushes elsewhere.
    TierDegraded(String, u32),
    /// The active-backend daemon hosting the runtime dies after *acking*
    /// the final wave (payloads journaled, fsynced) but before its async
    /// flushes drain, then restarts over the surviving storage. The WAL
    /// replay must settle every acked version and every wave must restore
    /// bit-for-bit — the paper's "a backend failure never loses an acked
    /// checkpoint". The failure scope is unused (the daemon dies, the
    /// application ranks survive) and must be pinned to rank 0.
    BackendCrash,
    /// Restart storm: after the checkpoint waves settle, N restart clients
    /// cold-restore the same job through one daemon. Mid-storm the daemon
    /// is killed and restarted over the surviving storage; the remaining
    /// clients finish against the fresh incarnation. Every client must get
    /// bit-for-bit bytes, the restore plane's read-through cache and
    /// single-flight table must collapse the redundant tier reads, and a
    /// deliberately poisoned cache entry must be detected by its
    /// fingerprint and refetched — never served. Like `BackendCrash`, the
    /// failure scope is unused and pinned to rank 0.
    RestartStorm(usize),
}

impl InjectionPoint {
    pub fn name(&self) -> String {
        match self {
            InjectionPoint::AfterCheckpoint => "after-checkpoint".to_string(),
            InjectionPoint::BeforeModule(m) => format!("before-module:{m}"),
            InjectionPoint::MidFlushChunk(c) => format!("mid-flush-chunk:{c}"),
            InjectionPoint::MidDrainPreIndex => "mid-drain-pre-index".to_string(),
            InjectionPoint::MidRestart(k) => format!("mid-restart:{k}"),
            InjectionPoint::DeltaChainBreak(b) => format!("delta-chain-break:{b}"),
            InjectionPoint::DeltaGcCrash => "delta-gc-crash".to_string(),
            InjectionPoint::TierOutage(t) => format!("tier-outage:{t}"),
            InjectionPoint::TierDegraded(t, f) => format!("tier-degraded:{t}x{f}"),
            InjectionPoint::BackendCrash => "backend-crash".to_string(),
            InjectionPoint::RestartStorm(n) => format!("restart-storm:{n}"),
        }
    }

    fn to_json(&self) -> Json {
        match self {
            InjectionPoint::AfterCheckpoint => Json::obj().set("point", "after-checkpoint"),
            InjectionPoint::BeforeModule(m) => Json::obj()
                .set("point", "before-module")
                .set("module", m.as_str()),
            InjectionPoint::MidFlushChunk(c) => Json::obj()
                .set("point", "mid-flush-chunk")
                .set("chunk", *c),
            InjectionPoint::MidDrainPreIndex => {
                Json::obj().set("point", "mid-drain-pre-index")
            }
            InjectionPoint::MidRestart(k) => Json::obj()
                .set("point", "mid-restart")
                .set("after_ranks", *k),
            InjectionPoint::DeltaChainBreak(b) => Json::obj()
                .set("point", "delta-chain-break")
                .set("back", *b),
            InjectionPoint::DeltaGcCrash => Json::obj().set("point", "delta-gc-crash"),
            InjectionPoint::TierOutage(t) => Json::obj()
                .set("point", "tier-outage")
                .set("tier", t.as_str()),
            InjectionPoint::TierDegraded(t, f) => Json::obj()
                .set("point", "tier-degraded")
                .set("tier", t.as_str())
                .set("factor", *f as u64),
            InjectionPoint::BackendCrash => Json::obj().set("point", "backend-crash"),
            InjectionPoint::RestartStorm(n) => Json::obj()
                .set("point", "restart-storm")
                .set("clients", *n),
        }
    }

    fn from_json(j: &Json) -> Result<Self> {
        match j.str_or("point", "after-checkpoint") {
            "after-checkpoint" => Ok(InjectionPoint::AfterCheckpoint),
            "before-module" => Ok(InjectionPoint::BeforeModule(
                j.get("module")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("before-module needs a \"module\""))?
                    .to_string(),
            )),
            "mid-flush-chunk" => Ok(InjectionPoint::MidFlushChunk(j.usize_or("chunk", 1))),
            "mid-drain-pre-index" => Ok(InjectionPoint::MidDrainPreIndex),
            "mid-restart" => Ok(InjectionPoint::MidRestart(j.usize_or("after_ranks", 1))),
            "delta-chain-break" => Ok(InjectionPoint::DeltaChainBreak(j.usize_or("back", 1))),
            "delta-gc-crash" => Ok(InjectionPoint::DeltaGcCrash),
            "tier-outage" => Ok(InjectionPoint::TierOutage(
                j.get("tier")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("tier-outage needs a \"tier\""))?
                    .to_string(),
            )),
            "tier-degraded" => Ok(InjectionPoint::TierDegraded(
                j.get("tier")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("tier-degraded needs a \"tier\""))?
                    .to_string(),
                j.usize_or("factor", 16) as u32,
            )),
            "backend-crash" => Ok(InjectionPoint::BackendCrash),
            "restart-storm" => Ok(InjectionPoint::RestartStorm(j.usize_or("clients", 8))),
            other => bail!("unknown injection point {other}"),
        }
    }
}

/// How exactly the `FailureScope::min_level` contract is asserted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ContractMode {
    /// The restorable frontier must equal the model's prediction exactly.
    Strict,
    /// The actual frontier may exceed the prediction: the pre-index crash
    /// leaves a durable container the completion bookkeeping never saw.
    AtLeast,
}

/// One fully-specified scenario. Everything the run does — workload
/// mutations, failure targets, injection timing — derives from these
/// fields, so `seed + spec` is a complete one-line repro.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    pub seed: u64,
    pub nodes: usize,
    pub ranks_per_node: usize,
    pub engine_mode: EngineMode,
    pub tier_policy: TierPolicy,
    pub with_partner: bool,
    /// 0 disables the erasure module.
    pub erasure_group: usize,
    /// Route level-4 flushes through the write-combining aggregator.
    pub aggregation: bool,
    /// Incremental deduplicated checkpointing (content-defined chunking,
    /// delta manifests, chains of [`DELTA_MAX_CHAIN`]).
    pub delta: bool,
    /// Adaptive tier placement policy (`static` / `fastest-eligible` /
    /// `capacity-aware`); None runs the legacy fixed-target routing.
    /// Placement scenarios provision the burst buffer so failover and
    /// adaptive routing have somewhere to go.
    pub placement: Option<String>,
    /// Checkpoint waves taken before the failure.
    pub waves: u64,
    /// Application steps between checkpoints (version = step count).
    pub steps_per_wave: u64,
    pub regions: usize,
    pub region_bytes: usize,
    pub scope: ScopeSpec,
    pub inject: InjectionPoint,
}

impl ScenarioSpec {
    pub fn topology(&self) -> Topology {
        Topology::new(self.nodes, self.ranks_per_node)
    }

    pub fn contract(&self) -> ContractMode {
        match self.inject {
            InjectionPoint::MidDrainPreIndex => ContractMode::AtLeast,
            // The break can only strand chunks that later deltas still
            // reference; a mutation landing exactly on the broken
            // version's novel chunks would leave newer versions
            // self-sufficient, so the chain model is a guaranteed lower
            // bound rather than an exact prediction.
            InjectionPoint::DeltaChainBreak(_) => ContractMode::AtLeast,
            _ => ContractMode::Strict,
        }
    }

    /// The checkpointed versions a delta restore of `version` may touch:
    /// the nearest forced full at or below it, up through `version`
    /// itself. Mirrors `DeltaState`'s chain policy (first checkpoint
    /// full, a forced full every [`DELTA_MAX_CHAIN`] checkpoints).
    pub fn delta_chain_versions(&self, version: u64) -> Vec<u64> {
        let spw = self.steps_per_wave.max(1);
        let idx = version / spw; // 1-based checkpoint index
        if idx == 0 {
            return vec![version];
        }
        let full_idx = ((idx - 1) / DELTA_MAX_CHAIN) * DELTA_MAX_CHAIN + 1;
        (full_idx..=idx).map(|i| i * spw).collect()
    }

    /// The runtime configuration this scenario runs under. Deterministic
    /// choices: a single backend thread (FIFO async tails), the
    /// low-priority scheduler (tails run at `Priority::Background`, which
    /// lets the runner hold them behind a pause barrier until every
    /// rank's blocking prefix ran; its gate pacing is microseconds per
    /// 4 KiB chunk and records nothing in the trace), a large age
    /// threshold (no wall-clock drains) and enough retained versions that
    /// GC never interferes.
    pub fn to_config(&self) -> VelocConfig {
        let mut cfg = VelocConfig::default().with_nodes(self.nodes, self.ranks_per_node);
        cfg.engine_mode = self.engine_mode;
        cfg.scheduler = SchedulerPolicy::LowPriority;
        cfg.backend_threads = 1;
        cfg.wait_timeout = Duration::from_secs(30);
        cfg.stack.tier_policy = self.tier_policy;
        cfg.stack.with_partner = self.with_partner;
        cfg.stack.erasure_group = self.erasure_group;
        cfg.stack.keep_versions = 64;
        cfg.stack.flush_chunk = 4096;
        cfg.stack.erasure_timeout = Duration::from_millis(200);
        cfg.aggregation.enabled = self.aggregation;
        cfg.aggregation.drain_chunk = 4096;
        cfg.aggregation.max_delay = Duration::from_secs(120);
        if let Some(policy) = &self.placement {
            cfg.placement.enabled = true;
            cfg.placement.policy = crate::storage::PlacementPolicy::parse(policy)
                .expect("validate() checked the policy spelling");
            cfg.fabric.with_burst_buffer = true;
        }
        if self.delta {
            cfg.delta.enabled = true;
            // Region sizes are a few KiB: chunk small so one region spans
            // many chunks and single-slice mutations stay O(1) chunks.
            cfg.delta.min_chunk = 64;
            cfg.delta.avg_chunk = 256;
            cfg.delta.max_chunk = 1024;
            cfg.delta.max_chain = DELTA_MAX_CHAIN;
        }
        if matches!(self.inject, InjectionPoint::DeltaGcCrash) {
            // The GC-crash window only opens when version GC actually
            // fires: retain little, checkpoint often.
            cfg.stack.keep_versions = 2;
        }
        cfg
    }

    /// One-line exact repro for this scenario.
    pub fn repro(&self) -> String {
        format!("veloc sim --json '{}'", self.to_json().to_string())
    }

    pub fn to_json(&self) -> Json {
        // The seed serializes as a string: Json numbers are f64-backed and
        // would silently round seeds above 2^53, breaking the exact-repro
        // guarantee.
        let j = Json::obj()
            .set("seed", self.seed.to_string())
            .set("nodes", self.nodes)
            .set("ranks_per_node", self.ranks_per_node)
            .set(
                "engine_mode",
                match self.engine_mode {
                    EngineMode::Sync => "sync",
                    EngineMode::Async => "async",
                },
            )
            .set(
                "tier_policy",
                match self.tier_policy {
                    TierPolicy::FastestFirst => "fastest",
                    TierPolicy::ConcurrencyAware => "concurrency-aware",
                },
            )
            .set("partner", self.with_partner)
            .set("erasure_group", self.erasure_group)
            .set("aggregation", self.aggregation)
            .set("delta", self.delta);
        let j = match &self.placement {
            Some(p) => j.set("placement", p.as_str()),
            None => j,
        };
        j.set("waves", self.waves)
            .set("steps_per_wave", self.steps_per_wave)
            .set("regions", self.regions)
            .set("region_bytes", self.region_bytes)
            .set("scope", self.scope.to_json())
            .set("inject", self.inject.to_json())
    }

    fn placement_from_json(j: &Json) -> Option<String> {
        j.get("placement")
            .and_then(Json::as_str)
            .map(str::to_string)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let seed = match j.get("seed") {
            None => 1,
            Some(Json::Str(s)) => s
                .parse::<u64>()
                .map_err(|_| anyhow!("seed must be a u64, got {s:?}"))?,
            Some(n) => n
                .as_u64()
                .ok_or_else(|| anyhow!("seed must be a non-negative integer"))?,
        };
        let spec = ScenarioSpec {
            seed,
            nodes: j.usize_or("nodes", 4),
            ranks_per_node: j.usize_or("ranks_per_node", 2),
            engine_mode: match j.str_or("engine_mode", "async") {
                "sync" => EngineMode::Sync,
                "async" => EngineMode::Async,
                other => bail!("engine_mode must be sync|async, got {other}"),
            },
            tier_policy: match j.str_or("tier_policy", "fastest") {
                "fastest" => TierPolicy::FastestFirst,
                "concurrency-aware" => TierPolicy::ConcurrencyAware,
                other => bail!("unknown tier_policy {other}"),
            },
            with_partner: j.bool_or("partner", true),
            erasure_group: j.usize_or("erasure_group", 0),
            aggregation: j.bool_or("aggregation", false),
            delta: j.bool_or("delta", false),
            placement: Self::placement_from_json(j),
            waves: j.get("waves").and_then(Json::as_u64).unwrap_or(3),
            steps_per_wave: j.get("steps_per_wave").and_then(Json::as_u64).unwrap_or(2),
            regions: j.usize_or("regions", 2),
            region_bytes: j.usize_or("region_bytes", 4096),
            scope: ScopeSpec::from_json(
                j.get("scope").unwrap_or(&Json::Null),
            )?,
            inject: InjectionPoint::from_json(
                j.get("inject").unwrap_or(&Json::Null),
            )?,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn from_str_json(s: &str) -> Result<Self> {
        let j = Json::parse(s).map_err(|e| anyhow!("scenario json: {e}"))?;
        Self::from_json(&j)
    }

    /// Reject combinations the engine cannot run deterministically or that
    /// are internally inconsistent.
    pub fn validate(&self) -> Result<()> {
        if self.nodes < 2 || self.ranks_per_node == 0 {
            bail!("scenario needs >= 2 nodes and >= 1 rank per node");
        }
        if self.waves == 0 || self.steps_per_wave == 0 {
            bail!("scenario needs >= 1 wave and >= 1 step per wave");
        }
        if self.regions == 0 || self.region_bytes < 16 {
            bail!("scenario needs >= 1 region of >= 16 bytes");
        }
        if self.erasure_group == 1 {
            bail!("erasure_group 1 is meaningless (0 disables, >= 2 enables)");
        }
        if self.erasure_group >= 2 && self.nodes % self.erasure_group != 0 {
            bail!(
                "nodes ({}) must be a multiple of erasure_group ({})",
                self.nodes,
                self.erasure_group
            );
        }
        if self.scope.kind == ScopeKind::MultiNode && self.nodes < 3 {
            bail!("multi-node scope needs >= 3 nodes (else it is a system outage)");
        }
        if self.delta && self.erasure_group >= 2 {
            bail!(
                "delta scenarios exclude erasure: the contract model does not \
                 cover chain restores through group rebuilds (the module path \
                 itself is covered by integration tests)"
            );
        }
        if let Some(policy) = &self.placement {
            crate::storage::PlacementPolicy::parse(policy)?;
            if self.delta {
                bail!(
                    "placement scenarios exclude delta: the chain model is \
                     kept to the tested envelope (the placement restore path \
                     itself is tier-agnostic and covered by module tests)"
                );
            }
        }
        match &self.inject {
            InjectionPoint::AfterCheckpoint => {}
            InjectionPoint::MidRestart(after) => {
                let world = self.nodes * self.ranks_per_node;
                if *after == 0 || *after > world {
                    bail!(
                        "mid-restart after_ranks ({after}) must be in 1..={world} \
                         or the second failure never fires"
                    );
                }
            }
            InjectionPoint::BeforeModule(m) => {
                const KNOWN: [&str; 6] =
                    ["checksum", "local", "partner", "erasure", "transfer", "version"];
                if !KNOWN.contains(&m.as_str()) {
                    bail!("unknown boundary module {m} (one of {KNOWN:?})");
                }
                if m == "partner" && !self.with_partner {
                    bail!("boundary module partner requires the partner stage");
                }
                if m == "erasure" && self.erasure_group < 2 {
                    bail!("boundary module erasure requires erasure_group >= 2");
                }
            }
            InjectionPoint::MidFlushChunk(c) => {
                if *c == 0 {
                    bail!("mid-flush-chunk fuse must be >= 1");
                }
                if self.engine_mode == EngineMode::Sync && self.erasure_group >= 2 {
                    bail!(
                        "mid-flush-chunk with a sync engine + erasure needs threaded \
                         waves, which make chunk ordering nondeterministic"
                    );
                }
            }
            InjectionPoint::MidDrainPreIndex => {
                if !self.aggregation {
                    bail!("mid-drain-pre-index requires aggregation");
                }
                if self.with_partner || self.erasure_group >= 2 {
                    bail!(
                        "mid-drain-pre-index isolates the aggregated level: \
                         disable partner and erasure"
                    );
                }
                if self.scope.kind != ScopeKind::Node || self.scope.target != Some(0) {
                    bail!(
                        "mid-drain-pre-index fires on the first drained group: \
                         pin the scope to node 0"
                    );
                }
            }
            InjectionPoint::DeltaChainBreak(back) => {
                if !self.delta {
                    bail!("delta-chain-break requires delta");
                }
                if self.with_partner || self.aggregation {
                    bail!(
                        "delta-chain-break isolates the PFS chain: disable \
                         partner and aggregation"
                    );
                }
                if self.scope.kind != ScopeKind::Node {
                    bail!(
                        "delta-chain-break needs a node scope (the victims' \
                         local copies and chunk store must die)"
                    );
                }
                if *back == 0 || (*back as u64) >= self.waves {
                    bail!(
                        "delta-chain-break back ({back}) must be in 1..waves \
                         ({}) so a broken version exists below the last",
                        self.waves
                    );
                }
            }
            InjectionPoint::TierOutage(tier) => {
                if self.placement.is_none() {
                    bail!("tier-outage requires a placement policy");
                }
                if !["pfs", "burst-buffer"].contains(&tier.as_str()) {
                    bail!(
                        "tier-outage tier must be pfs|burst-buffer (the tiers \
                         placement scenarios provision), got {tier}"
                    );
                }
                if self.scope.kind == ScopeKind::System {
                    bail!(
                        "tier-outage under a system failure proves nothing: \
                         the burst-buffer fallback is wiped with the system"
                    );
                }
            }
            InjectionPoint::TierDegraded(tier, factor) => {
                match self.placement.as_deref() {
                    None => bail!("tier-degraded requires a placement policy"),
                    Some("static") => bail!(
                        "tier-degraded needs an adaptive policy \
                         (fastest-eligible or capacity-aware): static \
                         routing never reacts to observed slowdowns"
                    ),
                    Some(_) => {}
                }
                if !["pfs", "burst-buffer"].contains(&tier.as_str()) {
                    bail!(
                        "tier-degraded tier must be pfs|burst-buffer, got {tier}"
                    );
                }
                if *factor < 2 {
                    bail!("tier-degraded factor must be >= 2, got {factor}");
                }
                if self.waves < 3 {
                    bail!(
                        "tier-degraded needs >= 3 waves: one clean wave, one \
                         wave observing the slowdown, one wave routed away"
                    );
                }
            }
            InjectionPoint::BackendCrash => {
                if self.engine_mode == EngineMode::Sync {
                    bail!(
                        "backend-crash requires the async engine: a sync submit \
                         settles before the ack, leaving nothing for the journal \
                         replay to resume"
                    );
                }
                if self.erasure_group >= 2 {
                    bail!(
                        "backend-crash excludes erasure: the daemon dispatches \
                         sequentially, so erasure group members cannot \
                         rendezvous deterministically"
                    );
                }
                if self.delta {
                    bail!(
                        "backend-crash excludes delta: chunk-store state is \
                         daemon-local and outside this scenario's contract model"
                    );
                }
                if self.placement.is_some() {
                    bail!("backend-crash excludes placement: one injection per scenario");
                }
                if self.scope.kind != ScopeKind::Rank || self.scope.target != Some(0) {
                    bail!(
                        "backend-crash kills the daemon, not ranks — pin the \
                         (unused) scope to rank 0"
                    );
                }
            }
            InjectionPoint::RestartStorm(clients) => {
                if *clients < 2 {
                    bail!(
                        "restart-storm needs >= 2 clients (one client is a \
                         plain restart, not a storm), got {clients}"
                    );
                }
                if self.engine_mode == EngineMode::Sync {
                    bail!(
                        "restart-storm requires the async engine: the storm \
                         serves through the active-backend daemon"
                    );
                }
                if self.erasure_group >= 2 {
                    bail!(
                        "restart-storm excludes erasure: the daemon dispatches \
                         sequentially, so erasure group members cannot \
                         rendezvous deterministically"
                    );
                }
                if self.delta {
                    bail!(
                        "restart-storm excludes delta: chunk-store state is \
                         daemon-local and outside this scenario's contract model"
                    );
                }
                if self.placement.is_some() {
                    bail!("restart-storm excludes placement: one injection per scenario");
                }
                if self.scope.kind != ScopeKind::Rank || self.scope.target != Some(0) {
                    bail!(
                        "restart-storm kills the daemon, not ranks — pin the \
                         (unused) scope to rank 0"
                    );
                }
            }
            InjectionPoint::DeltaGcCrash => {
                if !self.delta {
                    bail!("delta-gc-crash requires delta");
                }
                if self.with_partner || self.aggregation {
                    bail!("delta-gc-crash isolates the GC path: disable partner and aggregation");
                }
                if self.scope.kind != ScopeKind::Rank || self.scope.target.is_none() {
                    bail!(
                        "delta-gc-crash needs a pinned rank scope (storage \
                         must survive; only the GC writer dies)"
                    );
                }
                if self.ranks_per_node < 2 {
                    bail!(
                        "delta-gc-crash needs >= 2 ranks per node so a \
                         surviving writer on the node replays the ledger"
                    );
                }
                if self.waves < 5 {
                    bail!(
                        "delta-gc-crash needs >= 5 waves: earlier GC passes \
                         are fully pinned by chain ancestors"
                    );
                }
            }
        }
        Ok(())
    }
}

/// Baseline spec the matrix derives from (4 nodes x 2 ranks, async engine,
/// partner + 4-wide erasure, 3 waves of 2 steps).
pub fn base_spec(seed: u64) -> ScenarioSpec {
    ScenarioSpec {
        seed,
        nodes: 4,
        ranks_per_node: 2,
        engine_mode: EngineMode::Async,
        tier_policy: TierPolicy::FastestFirst,
        with_partner: true,
        erasure_group: 4,
        aggregation: false,
        delta: false,
        placement: None,
        waves: 3,
        steps_per_wave: 2,
        regions: 2,
        region_bytes: 4096,
        scope: ScopeSpec {
            kind: ScopeKind::Node,
            target: None,
        },
        inject: InjectionPoint::AfterCheckpoint,
    }
}

/// The standard sweep: module-stack permutations (sync/async engine, XOR
/// partner vs erasure group sizes, aggregation on/off, delta on/off, tier
/// policies, placement policies, the out-of-process backend daemon)
/// crossed with every injection-point family. 44 scenarios; each is an
/// independent one-line repro.
pub fn standard_matrix(base_seed: u64) -> Vec<ScenarioSpec> {
    let s = |i: u64| base_seed.wrapping_add(i.wrapping_mul(7919));
    let scope = |kind: ScopeKind| ScopeSpec { kind, target: None };
    let node0 = ScopeSpec {
        kind: ScopeKind::Node,
        target: Some(0),
    };
    let before = |m: &str| InjectionPoint::BeforeModule(m.to_string());

    let mut specs = Vec::new();

    // Stack 1: async, partner, erasure x4.
    let s1 = base_spec(0);
    specs.push(ScenarioSpec { seed: s(1), scope: scope(ScopeKind::Node), ..s1.clone() });
    specs.push(ScenarioSpec { seed: s(2), scope: scope(ScopeKind::System), ..s1.clone() });
    specs.push(ScenarioSpec { seed: s(3), scope: scope(ScopeKind::Node), inject: before("transfer"), ..s1.clone() });
    specs.push(ScenarioSpec { seed: s(4), scope: scope(ScopeKind::MultiNode), inject: before("erasure"), ..s1.clone() });
    specs.push(ScenarioSpec { seed: s(5), scope: scope(ScopeKind::Node), inject: before("local"), ..s1.clone() });
    specs.push(ScenarioSpec { seed: s(6), scope: scope(ScopeKind::Node), inject: InjectionPoint::MidFlushChunk(2), ..s1.clone() });
    specs.push(ScenarioSpec { seed: s(7), scope: scope(ScopeKind::Rank), inject: InjectionPoint::MidFlushChunk(5), ..s1.clone() });
    specs.push(ScenarioSpec { seed: s(8), scope: scope(ScopeKind::Node), inject: InjectionPoint::MidRestart(3), ..s1.clone() });

    // Stack 2: sync engine, partner, erasure x4 (threaded waves).
    let s2 = ScenarioSpec { engine_mode: EngineMode::Sync, ..base_spec(0) };
    specs.push(ScenarioSpec { seed: s(9), scope: scope(ScopeKind::Node), ..s2.clone() });
    specs.push(ScenarioSpec { seed: s(10), scope: scope(ScopeKind::MultiNode), inject: before("partner"), ..s2.clone() });
    specs.push(ScenarioSpec { seed: s(11), scope: scope(ScopeKind::Rank), inject: InjectionPoint::MidRestart(1), ..s2.clone() });
    specs.push(ScenarioSpec { seed: s(12), scope: scope(ScopeKind::MultiNode), ..s2.clone() });

    // Stack 3: async, partner only (no erasure), concurrency-aware tiers.
    let s3 = ScenarioSpec {
        erasure_group: 0,
        tier_policy: TierPolicy::ConcurrencyAware,
        ..base_spec(0)
    };
    specs.push(ScenarioSpec { seed: s(13), scope: scope(ScopeKind::Node), ..s3.clone() });
    specs.push(ScenarioSpec { seed: s(14), scope: scope(ScopeKind::Node), inject: before("transfer"), ..s3.clone() });
    specs.push(ScenarioSpec { seed: s(15), scope: scope(ScopeKind::MultiNode), inject: InjectionPoint::MidFlushChunk(3), ..s3.clone() });
    specs.push(ScenarioSpec { seed: s(16), scope: scope(ScopeKind::System), inject: InjectionPoint::MidRestart(2), ..s3.clone() });

    // Stack 4: async, erasure x2 only (no partner).
    let s4 = ScenarioSpec {
        with_partner: false,
        erasure_group: 2,
        ..base_spec(0)
    };
    specs.push(ScenarioSpec { seed: s(17), scope: scope(ScopeKind::MultiNode), ..s4.clone() });
    specs.push(ScenarioSpec { seed: s(18), scope: scope(ScopeKind::Node), inject: before("erasure"), ..s4.clone() });
    specs.push(ScenarioSpec { seed: s(19), scope: scope(ScopeKind::Node), inject: InjectionPoint::MidFlushChunk(1), ..s4.clone() });
    specs.push(ScenarioSpec { seed: s(20), scope: scope(ScopeKind::Rank), ..s4.clone() });

    // Stack 5: async, aggregated flush only (no partner/erasure).
    let s5 = ScenarioSpec {
        with_partner: false,
        erasure_group: 0,
        aggregation: true,
        ..base_spec(0)
    };
    specs.push(ScenarioSpec { seed: s(21), scope: scope(ScopeKind::Node), ..s5.clone() });
    specs.push(ScenarioSpec { seed: s(22), scope: scope(ScopeKind::Node), inject: InjectionPoint::MidFlushChunk(2), ..s5.clone() });
    specs.push(ScenarioSpec { seed: s(23), scope: node0, inject: InjectionPoint::MidDrainPreIndex, ..s5.clone() });
    specs.push(ScenarioSpec { seed: s(24), scope: scope(ScopeKind::Node), inject: InjectionPoint::MidRestart(2), ..s5.clone() });
    specs.push(ScenarioSpec { seed: s(25), scope: scope(ScopeKind::System), ..s5.clone() });

    // Stack 6: sync engine + aggregated flush.
    let s6 = ScenarioSpec {
        engine_mode: EngineMode::Sync,
        with_partner: false,
        erasure_group: 0,
        aggregation: true,
        ..base_spec(0)
    };
    specs.push(ScenarioSpec { seed: s(26), scope: scope(ScopeKind::Node), ..s6.clone() });
    specs.push(ScenarioSpec { seed: s(27), scope: node0, inject: InjectionPoint::MidDrainPreIndex, ..s6.clone() });
    specs.push(ScenarioSpec { seed: s(28), scope: scope(ScopeKind::Node), inject: before("transfer"), ..s6.clone() });

    // Stack 7: incremental dedup (delta) — local + PFS chain only.
    let s7 = ScenarioSpec {
        with_partner: false,
        erasure_group: 0,
        delta: true,
        ..base_spec(0)
    };
    specs.push(ScenarioSpec { seed: s(29), scope: scope(ScopeKind::Node), ..s7.clone() });
    specs.push(ScenarioSpec { seed: s(30), scope: scope(ScopeKind::System), ..s7.clone() });
    specs.push(ScenarioSpec { seed: s(31), scope: scope(ScopeKind::Node), inject: InjectionPoint::MidFlushChunk(2), ..s7.clone() });
    // Torn mid-chain flush: manifest durable, chunks gone — recovery must
    // fall back past the break (here to the last forced full).
    specs.push(ScenarioSpec {
        seed: s(32),
        waves: 6,
        steps_per_wave: 1,
        scope: scope(ScopeKind::Node),
        inject: InjectionPoint::DeltaChainBreak(1),
        ..s7.clone()
    });
    // GC writer dies post-intent: the refcount ledger replay finishes the
    // collection and every retained version stays restorable.
    specs.push(ScenarioSpec {
        seed: s(33),
        waves: 5,
        steps_per_wave: 1,
        scope: ScopeSpec { kind: ScopeKind::Rank, target: Some(0) },
        inject: InjectionPoint::DeltaGcCrash,
        ..s7.clone()
    });
    // Delta + partner replication: victims reassemble through the chain
    // of partner copies on the surviving node.
    specs.push(ScenarioSpec { seed: s(34), with_partner: true, scope: scope(ScopeKind::Node), ..s7.clone() });
    // Delta + aggregation: manifests and novel chunks ride in VAGG
    // containers; chain restores read back through the segment index.
    specs.push(ScenarioSpec { seed: s(35), aggregation: true, scope: scope(ScopeKind::Node), ..s7.clone() });

    // Stack 8: adaptive tier placement over pfs + burst buffer (no
    // partner/erasure, so victims must restore from wherever the level-4
    // flush landed).
    let s8 = ScenarioSpec {
        with_partner: false,
        erasure_group: 0,
        placement: Some("static".to_string()),
        ..base_spec(0)
    };
    // Primary outage mid-run: the final wave's direct flushes fail over
    // to the burst buffer; restores find them there (the pfs stays down).
    specs.push(ScenarioSpec {
        seed: s(36),
        scope: scope(ScopeKind::Node),
        inject: InjectionPoint::TierOutage("pfs".to_string()),
        ..s8.clone()
    });
    // Same outage under aggregation: whole containers fail over and the
    // segment index records the destination tier.
    specs.push(ScenarioSpec {
        seed: s(37),
        aggregation: true,
        scope: scope(ScopeKind::Node),
        inject: InjectionPoint::TierOutage("pfs".to_string()),
        ..s8.clone()
    });
    // Degraded-tier adaptation: fastest-eligible starts on the burst
    // buffer, observes the slowdown, and routes the final wave to the pfs.
    specs.push(ScenarioSpec {
        seed: s(38),
        placement: Some("fastest-eligible".to_string()),
        waves: 4,
        scope: scope(ScopeKind::Node),
        inject: InjectionPoint::TierDegraded("burst-buffer".to_string(), 32),
        ..s8.clone()
    });
    // Capacity-aware placement under a plain node failure: routing spread
    // across the pool must not cost any recoverability.
    specs.push(ScenarioSpec {
        seed: s(39),
        placement: Some("capacity-aware".to_string()),
        scope: scope(ScopeKind::Node),
        ..s8.clone()
    });

    // Stack 9: the active backend itself is the failure domain. The
    // daemon acks the final wave (journal fsynced) with its flushes still
    // pending, dies, restarts over the surviving storage: the WAL replay
    // must settle every acked version and every wave must restore
    // bit-for-bit. The rank-0 scope is pinned but unused (ranks survive).
    let rank0 = ScopeSpec {
        kind: ScopeKind::Rank,
        target: Some(0),
    };
    let s9 = ScenarioSpec {
        erasure_group: 0,
        scope: rank0,
        inject: InjectionPoint::BackendCrash,
        ..base_spec(0)
    };
    // Partner replication alongside the daemon's journal.
    specs.push(ScenarioSpec { seed: s(40), ..s9.clone() });
    // Local + PFS only: the replayed flush is the sole remote copy.
    specs.push(ScenarioSpec { seed: s(41), with_partner: false, ..s9.clone() });
    // Aggregated drains resume from the journal through fresh containers.
    specs.push(ScenarioSpec {
        seed: s(42),
        with_partner: false,
        aggregation: true,
        ..s9.clone()
    });

    // Stack 10: restart storm — many clients cold-restore the same wave
    // through one daemon, which dies and restarts mid-storm. The restore
    // plane must collapse the redundant reads (cache + single-flight) and
    // still hand every client bit-for-bit bytes.
    let s10 = ScenarioSpec {
        inject: InjectionPoint::RestartStorm(8),
        ..s9.clone()
    };
    specs.push(ScenarioSpec { seed: s(43), ..s10.clone() });
    // The storm served out of aggregated containers: every extraction goes
    // through the segment index and the same shared cache.
    specs.push(ScenarioSpec {
        seed: s(44),
        with_partner: false,
        aggregation: true,
        ..s10
    });

    specs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_json_roundtrip() {
        for spec in standard_matrix(42) {
            let back = ScenarioSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(back, spec);
        }
    }

    #[test]
    fn repro_is_one_line_and_parseable() {
        let spec = base_spec(7);
        let repro = spec.repro();
        assert!(!repro.contains('\n'));
        let json = repro
            .strip_prefix("veloc sim --json '")
            .and_then(|s| s.strip_suffix('\''))
            .unwrap();
        assert_eq!(ScenarioSpec::from_str_json(json).unwrap(), spec);
    }

    #[test]
    fn huge_seeds_roundtrip_exactly() {
        // Above 2^53: a float-backed number would round; the string form
        // must not.
        let spec = base_spec(u64::MAX - 12345);
        let back = ScenarioSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.seed, u64::MAX - 12345);
        // Plain numeric seeds (hand-written specs) still parse.
        let j = Json::parse(r#"{"seed": 42}"#).unwrap();
        assert_eq!(ScenarioSpec::from_json(&j).unwrap().seed, 42);
    }

    #[test]
    fn matrix_is_large_and_valid() {
        let specs = standard_matrix(1);
        assert!(specs.len() >= 30, "{} scenarios", specs.len());
        for spec in &specs {
            spec.validate().unwrap();
        }
        // Distinct (stack, injection) combinations.
        let mut combos = std::collections::BTreeSet::new();
        for spec in &specs {
            combos.insert(format!(
                "{:?}/{}/{}/{}/{}/{}",
                spec.engine_mode,
                spec.with_partner,
                spec.erasure_group,
                spec.aggregation,
                spec.delta,
                spec.inject.name()
            ));
        }
        assert!(combos.len() >= 28, "{} distinct combos", combos.len());
    }

    #[test]
    fn scope_resolution_is_seed_deterministic() {
        let topo = Topology::new(4, 2);
        let sc = ScopeSpec { kind: ScopeKind::Node, target: None };
        assert_eq!(sc.resolve(&topo, 9), sc.resolve(&topo, 9));
        let pinned = ScopeSpec { kind: ScopeKind::MultiNode, target: Some(3) };
        assert_eq!(
            pinned.resolve(&topo, 1),
            FailureScope::MultiNode(vec![3, 0])
        );
    }

    #[test]
    fn delta_chain_versions_follow_forced_fulls() {
        let mut spec = base_spec(1);
        spec.delta = true;
        spec.erasure_group = 0;
        spec.waves = 6;
        spec.steps_per_wave = 1;
        assert_eq!(spec.delta_chain_versions(1), vec![1]);
        assert_eq!(spec.delta_chain_versions(3), vec![1, 2, 3]);
        assert_eq!(spec.delta_chain_versions(4), vec![4], "4th checkpoint is a forced full");
        assert_eq!(spec.delta_chain_versions(6), vec![4, 5, 6]);
        spec.steps_per_wave = 2;
        assert_eq!(spec.delta_chain_versions(8), vec![8]);
        assert_eq!(spec.delta_chain_versions(6), vec![2, 4, 6]);
    }

    #[test]
    fn delta_specs_validated() {
        let delta_base = ScenarioSpec {
            delta: true,
            erasure_group: 0,
            with_partner: false,
            ..base_spec(1)
        };
        delta_base.validate().unwrap();
        // Delta + erasure is outside the contract model.
        let mut bad = delta_base.clone();
        bad.erasure_group = 4;
        assert!(bad.validate().is_err());
        // Chain break must leave a version below the last.
        let mut bad = delta_base.clone();
        bad.scope = ScopeSpec { kind: ScopeKind::Node, target: None };
        bad.inject = InjectionPoint::DeltaChainBreak(bad.waves as usize);
        assert!(bad.validate().is_err());
        // GC crash needs a pinned rank scope and enough waves.
        let mut bad = delta_base.clone();
        bad.inject = InjectionPoint::DeltaGcCrash;
        bad.waves = 5;
        bad.scope = ScopeSpec { kind: ScopeKind::Node, target: Some(0) };
        assert!(bad.validate().is_err());
        let mut ok = delta_base;
        ok.inject = InjectionPoint::DeltaGcCrash;
        ok.waves = 5;
        ok.scope = ScopeSpec { kind: ScopeKind::Rank, target: Some(0) };
        ok.validate().unwrap();
    }

    #[test]
    fn tier_injection_specs_validated() {
        let placement_base = ScenarioSpec {
            with_partner: false,
            erasure_group: 0,
            placement: Some("static".to_string()),
            ..base_spec(1)
        };
        placement_base.validate().unwrap();
        // Tier injections require placement.
        let mut bad = base_spec(1);
        bad.inject = InjectionPoint::TierOutage("pfs".to_string());
        assert!(bad.validate().is_err());
        // Unknown tier id.
        let mut bad = placement_base.clone();
        bad.inject = InjectionPoint::TierOutage("floppy".to_string());
        assert!(bad.validate().is_err());
        // System scope wipes the fallback: rejected.
        let mut bad = placement_base.clone();
        bad.scope = ScopeSpec { kind: ScopeKind::System, target: None };
        bad.inject = InjectionPoint::TierOutage("pfs".to_string());
        assert!(bad.validate().is_err());
        // Degradation needs an adaptive policy and enough waves.
        let mut bad = placement_base.clone();
        bad.inject = InjectionPoint::TierDegraded("burst-buffer".to_string(), 32);
        bad.waves = 4;
        assert!(bad.validate().is_err(), "static policy cannot adapt");
        let mut ok = bad.clone();
        ok.placement = Some("fastest-eligible".to_string());
        ok.validate().unwrap();
        ok.waves = 2;
        assert!(ok.validate().is_err(), "needs >= 3 waves");
        // Bogus policy spelling.
        let mut bad = placement_base.clone();
        bad.placement = Some("psychic".to_string());
        assert!(bad.validate().is_err());
        // Placement + delta outside the contract envelope.
        let mut bad = placement_base;
        bad.delta = true;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn backend_crash_specs_validated() {
        let ok = ScenarioSpec {
            erasure_group: 0,
            scope: ScopeSpec { kind: ScopeKind::Rank, target: Some(0) },
            inject: InjectionPoint::BackendCrash,
            ..base_spec(1)
        };
        ok.validate().unwrap();
        // Sync engine settles at submit: nothing pending to replay.
        let mut bad = ok.clone();
        bad.engine_mode = EngineMode::Sync;
        assert!(bad.validate().is_err());
        // Erasure needs concurrent group members; the daemon dispatches
        // sequentially.
        let mut bad = ok.clone();
        bad.erasure_group = 4;
        assert!(bad.validate().is_err());
        // The scope is unused and must be pinned.
        let mut bad = ok.clone();
        bad.scope = ScopeSpec { kind: ScopeKind::Node, target: Some(0) };
        assert!(bad.validate().is_err());
        let mut bad = ok.clone();
        bad.scope = ScopeSpec { kind: ScopeKind::Rank, target: None };
        assert!(bad.validate().is_err());
        // Delta / placement are outside the modeled envelope.
        let mut bad = ok.clone();
        bad.delta = true;
        assert!(bad.validate().is_err());
        let mut bad = ok;
        bad.placement = Some("static".to_string());
        assert!(bad.validate().is_err());
    }

    #[test]
    fn restart_storm_specs_validated() {
        let ok = ScenarioSpec {
            erasure_group: 0,
            scope: ScopeSpec { kind: ScopeKind::Rank, target: Some(0) },
            inject: InjectionPoint::RestartStorm(8),
            ..base_spec(1)
        };
        ok.validate().unwrap();
        // One client is a plain restart, not a storm.
        let mut bad = ok.clone();
        bad.inject = InjectionPoint::RestartStorm(1);
        assert!(bad.validate().is_err());
        // The storm serves through the daemon: async only.
        let mut bad = ok.clone();
        bad.engine_mode = EngineMode::Sync;
        assert!(bad.validate().is_err());
        // The scope is unused and must be pinned to rank 0.
        let mut bad = ok.clone();
        bad.scope = ScopeSpec { kind: ScopeKind::Node, target: Some(0) };
        assert!(bad.validate().is_err());
        // Erasure / delta / placement are outside the modeled envelope.
        let mut bad = ok.clone();
        bad.erasure_group = 4;
        assert!(bad.validate().is_err());
        let mut bad = ok.clone();
        bad.delta = true;
        assert!(bad.validate().is_err());
        let mut bad = ok;
        bad.placement = Some("static".to_string());
        assert!(bad.validate().is_err());
    }

    #[test]
    fn invalid_specs_rejected() {
        let mut bad = base_spec(1);
        bad.erasure_group = 3; // 4 % 3 != 0
        assert!(bad.validate().is_err());
        let mut bad = base_spec(1);
        bad.inject = InjectionPoint::BeforeModule("warp".to_string());
        assert!(bad.validate().is_err());
        let mut bad = base_spec(1);
        bad.inject = InjectionPoint::MidDrainPreIndex; // no aggregation
        assert!(bad.validate().is_err());
        let mut bad = base_spec(1);
        bad.engine_mode = EngineMode::Sync;
        bad.inject = InjectionPoint::MidFlushChunk(1); // threaded + fuse
        assert!(bad.validate().is_err());
        let mut bad = base_spec(1);
        bad.inject = InjectionPoint::MidRestart(0); // never fires
        assert!(bad.validate().is_err());
        let mut bad = base_spec(1);
        bad.inject = InjectionPoint::MidRestart(9); // > world (8)
        assert!(bad.validate().is_err());
    }
}
