//! Seeded byte-corruption engine for the hostile-input suite.
//!
//! Every serialized artifact the runtime re-reads — wire frames, VAGG
//! containers, VDLT delta manifests, journal WALs, the segment-index JSON
//! — must satisfy one invariant under corruption: *parse returns a typed
//! error or a valid value; it never panics and never allocates off an
//! untrusted length*. The fuzz harness (`rust/fuzz/`) explores that
//! invariant with coverage guidance on nightly; this module is its
//! deterministic, tier-1-runnable twin: the same mutation families,
//! driven by [`Rng`] so every failure is reproducible from `(data, seed)`
//! alone.
//!
//! The engine is format-agnostic on purpose — it mutates bytes, not
//! schemas. The one format-aware helper is [`refresh_crc32_trailer`],
//! which re-seals the whole-buffer CRC32 that VAGG/VDLT carry in their
//! last four bytes: without it, most mutations die at the checksum gate
//! and the deeper header/length parsing paths go untested.

use crate::util::rng::Rng;

/// One family of deterministic byte mutations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mutation {
    /// Flip 1–8 individual bits at random offsets.
    BitFlip,
    /// Cut the buffer to a random proper prefix (torn write).
    Truncate,
    /// Overwrite a random 4-byte window with an enormous little-endian
    /// value — the classic hostile length-field inflation.
    InflateLength,
    /// Swap two equal-sized non-overlapping windows (record reordering /
    /// sector remap).
    Reorder,
    /// Zero a random run of bytes (hole punched by a failed write).
    ZeroRun,
}

impl Mutation {
    /// Every mutation family, in a stable order (seed decoding and the
    /// corruption matrices index into this).
    pub const ALL: [Mutation; 5] = [
        Mutation::BitFlip,
        Mutation::Truncate,
        Mutation::InflateLength,
        Mutation::Reorder,
        Mutation::ZeroRun,
    ];

    /// Stable lowercase name (failure messages, summary JSON).
    pub fn name(&self) -> &'static str {
        match self {
            Mutation::BitFlip => "bit-flip",
            Mutation::Truncate => "truncate",
            Mutation::InflateLength => "inflate-length",
            Mutation::Reorder => "reorder",
            Mutation::ZeroRun => "zero-run",
        }
    }
}

/// Apply the seed-selected mutation family to a copy of `data`. The same
/// `(data, seed)` pair always yields the same output; the chosen family
/// is returned so failures can name it.
pub fn mutate(data: &[u8], seed: u64) -> (Mutation, Vec<u8>) {
    let mut rng = Rng::new(seed);
    let m = Mutation::ALL[rng.below(Mutation::ALL.len() as u64) as usize];
    (m, apply(data, m, &mut rng))
}

/// Apply one specific mutation family using `rng` for its parameters.
/// Inputs too small for a family (e.g. reordering a 1-byte buffer) come
/// back as an unmodified copy — still a legal corruption-suite input, it
/// just exercises the unmutated path.
pub fn apply(data: &[u8], m: Mutation, rng: &mut Rng) -> Vec<u8> {
    let mut out = data.to_vec();
    match m {
        Mutation::BitFlip => {
            if out.is_empty() {
                return out;
            }
            let flips = 1 + rng.below(8) as usize;
            for _ in 0..flips {
                let at = rng.below(out.len() as u64) as usize;
                out[at] ^= 1 << rng.below(8);
            }
        }
        Mutation::Truncate => {
            if out.is_empty() {
                return out;
            }
            let keep = rng.below(out.len() as u64) as usize;
            out.truncate(keep);
        }
        Mutation::InflateLength => {
            if out.len() < 4 {
                return out;
            }
            let at = rng.below((out.len() - 3) as u64) as usize;
            // Bias toward the values that break naive length math:
            // u32::MAX (wraps 32-bit sums) and huge-but-plausible sizes
            // (drive unbounded allocation if unchecked).
            let val: u32 = match rng.below(3) {
                0 => u32::MAX,
                1 => u32::MAX - rng.below(64) as u32,
                _ => (1 << 30) + rng.below(1 << 30) as u32,
            };
            out[at..at + 4].copy_from_slice(&val.to_le_bytes());
        }
        Mutation::Reorder => {
            if out.len() < 2 {
                return out;
            }
            let win = 1 + rng.below((out.len() / 2) as u64) as usize;
            let a = rng.below((out.len() - 2 * win + 1) as u64) as usize;
            let b = a + win + rng.below((out.len() - a - 2 * win + 1) as u64) as usize;
            for i in 0..win {
                out.swap(a + i, b + i);
            }
        }
        Mutation::ZeroRun => {
            if out.is_empty() {
                return out;
            }
            let start = rng.below(out.len() as u64) as usize;
            let run = 1 + rng.below((out.len() - start) as u64) as usize;
            for byte in &mut out[start..start + run] {
                *byte = 0;
            }
        }
    }
    out
}

/// Re-seal the whole-buffer CRC32 trailer that VAGG and VDLT containers
/// carry in their final four bytes: CRC32 of everything before it. Call
/// after mutating such a container to push hostile bytes *past* the
/// checksum gate into the header/length parsing it protects. No-op on
/// buffers too short to carry a trailer.
pub fn refresh_crc32_trailer(buf: &mut [u8]) {
    if buf.len() < 4 {
        return;
    }
    let crc = crc32fast::hash(&buf[..buf.len() - 4]);
    let at = buf.len() - 4;
    buf[at..].copy_from_slice(&crc.to_le_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutations_are_deterministic() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        for seed in 0..50u64 {
            let (m1, a) = mutate(&data, seed);
            let (m2, b) = mutate(&data, seed);
            assert_eq!(m1, m2);
            assert_eq!(a, b, "seed {seed} must reproduce exactly");
        }
    }

    #[test]
    fn families_all_reachable_and_mostly_mutate() {
        let data: Vec<u8> = (0..=255u8).cycle().take(400).collect();
        let mut seen = std::collections::BTreeSet::new();
        let mut changed = 0usize;
        for seed in 0..200u64 {
            let (m, out) = mutate(&data, seed);
            seen.insert(m.name());
            if out != data {
                changed += 1;
            }
        }
        assert_eq!(seen.len(), Mutation::ALL.len(), "families seen: {seen:?}");
        assert!(changed > 150, "only {changed}/200 seeds mutated");
    }

    #[test]
    fn tiny_inputs_never_panic() {
        for len in 0..6usize {
            let data = vec![0xA5u8; len];
            for seed in 0..64u64 {
                let _ = mutate(&data, seed);
            }
            for m in Mutation::ALL {
                let mut rng = Rng::new(9);
                let _ = apply(&data, m, &mut rng);
            }
        }
    }

    #[test]
    fn reorder_preserves_multiset_and_length() {
        let data: Vec<u8> = (0..100u8).collect();
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let out = apply(&data, Mutation::Reorder, &mut rng);
            assert_eq!(out.len(), data.len());
            let mut a = out.clone();
            let mut b = data.clone();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn crc_trailer_refresh_matches_format_convention() {
        let mut buf = b"VAGGxxxxyyyyzzzz0000".to_vec();
        refresh_crc32_trailer(&mut buf);
        let stored = u32::from_le_bytes(buf[buf.len() - 4..].try_into().unwrap());
        assert_eq!(stored, crc32fast::hash(&buf[..buf.len() - 4]));
    }
}
