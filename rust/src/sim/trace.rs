//! Structured event traces: every scenario run emits an ordered list of
//! JSON events recorded exclusively by the single-threaded orchestrator,
//! so a run's trace is a pure function of its spec (seed included). Saved
//! traces replay exactly: re-running the embedded spec must reproduce the
//! event list byte for byte.

use crate::obs::FlightRecorder;
use crate::sim::scenario::ScenarioSpec;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::path::Path;
use std::sync::Arc;

#[derive(Clone, Debug, Default)]
pub struct Trace {
    events: Vec<Json>,
    /// Optional crash-durable mirror: every pushed event also appends to
    /// this flight stream (as a `sim.<ev>` record). The mirror is pure
    /// output — saved traces, diffs and replay comparisons never read it,
    /// so replay determinism is untouched.
    mirror: Option<Arc<FlightRecorder>>,
}

impl Trace {
    pub fn new() -> Self {
        Trace::default()
    }

    /// Attach a flight-stream mirror for all subsequently pushed events.
    pub fn set_mirror(&mut self, flight: Arc<FlightRecorder>) {
        self.mirror = Some(flight);
    }

    /// The attached flight mirror, if any.
    pub fn mirror(&self) -> Option<&Arc<FlightRecorder>> {
        self.mirror.as_ref()
    }

    pub fn push(&mut self, event: Json) {
        if let Some(f) = &self.mirror {
            f.event_json(&event);
        }
        self.events.push(event);
    }

    pub fn events(&self) -> &[Json] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Full trace document: the spec that produced it plus the events.
    pub fn to_json(&self, spec: &ScenarioSpec) -> Json {
        Json::obj()
            .set("scenario", spec.to_json())
            .set("events", Json::Arr(self.events.clone()))
    }

    pub fn save(&self, spec: &ScenarioSpec, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json(spec).to_pretty())
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))
    }

    /// Load a saved trace (spec + events) for replay.
    pub fn load(path: &Path) -> Result<(ScenarioSpec, Trace)> {
        let j = crate::util::json::load(path)?;
        let spec = ScenarioSpec::from_json(
            j.get("scenario")
                .ok_or_else(|| anyhow!("{}: no \"scenario\" object", path.display()))?,
        )?;
        let events = j
            .get("events")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("{}: no \"events\" array", path.display()))?
            .to_vec();
        Ok((
            spec,
            Trace {
                events,
                mirror: None,
            },
        ))
    }

    /// First divergence between this (recorded) trace and another
    /// (replayed) one; None = identical event streams.
    pub fn diff(&self, other: &Trace) -> Option<String> {
        let n = self.events.len().max(other.events.len());
        for i in 0..n {
            let a = self.events.get(i).map(Json::to_string);
            let b = other.events.get(i).map(Json::to_string);
            if a != b {
                return Some(format!(
                    "event {i} diverges:\n  recorded: {}\n  replayed: {}",
                    a.unwrap_or_else(|| "<missing>".to_string()),
                    b.unwrap_or_else(|| "<missing>".to_string()),
                ));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::scenario::base_spec;

    #[test]
    fn save_load_roundtrip() {
        let spec = base_spec(5);
        let mut t = Trace::new();
        t.push(Json::obj().set("ev", "start").set("seed", 5u64));
        t.push(Json::obj().set("ev", "end").set("ok", true));
        let dir = std::env::temp_dir().join("veloc-sim-trace-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        t.save(&spec, &path).unwrap();
        let (spec2, t2) = Trace::load(&path).unwrap();
        assert_eq!(spec2, spec);
        assert!(t.diff(&t2).is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn diff_reports_first_divergence() {
        let mut a = Trace::new();
        a.push(Json::obj().set("ev", "x"));
        a.push(Json::obj().set("ev", "y"));
        let mut b = Trace::new();
        b.push(Json::obj().set("ev", "x"));
        b.push(Json::obj().set("ev", "z"));
        let d = a.diff(&b).unwrap();
        assert!(d.contains("event 1"), "{d}");
        let mut c = Trace::new();
        c.push(Json::obj().set("ev", "x"));
        assert!(a.diff(&c).unwrap().contains("<missing>"));
    }
}
