//! Fuzz the journal WAL scanner (`backend/journal.rs`) — the parser
//! `Journal::open` replays through after a daemon crash.
//!
//! Invariant: `scan_records` returns normally for any byte image — a
//! hostile length prefix or corrupt CRC ends the scan (typed absence),
//! never panics, and never allocates off the untrusted length. Records
//! it does return are intact: re-framing them reproduces a prefix of
//! the input scan.

#![no_main]

use libfuzzer_sys::fuzz_target;
use veloc::backend::scan_records;

fuzz_target!(|data: &[u8]| {
    let records = scan_records(data);
    // Canonical re-encode: re-framing the scanned records yields an
    // image that scans to the same sequence.
    let mut reframed = Vec::new();
    for r in &records {
        let body = r.to_string().into_bytes();
        reframed.extend_from_slice(&(body.len() as u32).to_le_bytes());
        reframed.extend_from_slice(&body);
        reframed.extend_from_slice(&crc32fast::hash(&body).to_le_bytes());
    }
    assert_eq!(scan_records(&reframed), records, "scan not canonical");
});
