//! Fuzz the VAGG container header decoder and segment extraction
//! (`aggregation/container.rs`) — the exact code path the segment-index
//! rebuild walks over every container it finds on a tier.
//!
//! Invariant: `decode_header` returns `Ok` or a typed `ContainerError`;
//! a decoded header's declared lengths can never make `segment_offset`
//! overflow or `extract` slice out of bounds — hostile lengths degrade to
//! `SegmentOverrun`/`SegmentCrc`, never a panic.

#![no_main]

use libfuzzer_sys::fuzz_target;
use veloc::aggregation::container;

fuzz_target!(|data: &[u8]| {
    if let Ok(header) = container::decode_header(data) {
        for i in 0..header.segments.len() {
            // Offsets are derived from untrusted declared lengths; the
            // decode-time overflow check must make this total.
            let _ = header.segment_offset(i);
            let _ = container::extract(data, &header, i);
        }
        // Out-of-range indices are typed, not panics.
        assert!(container::extract(data, &header, header.segments.len()).is_err());
    }
});
