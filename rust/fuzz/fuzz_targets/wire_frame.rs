//! Fuzz the wire-protocol frame decoder (`backend/wire.rs`).
//!
//! Invariant: for arbitrary bytes, `read_frame` returns `Ok` or a typed
//! [`veloc::backend::wire::WireError`] — it never panics, and an input
//! that merely *declares* a huge header/body length costs bounded
//! allocation (the limits are checked before any buffer is reserved and
//! reads grow incrementally). A frame that decodes must re-encode
//! canonically: write → read reproduces the identical header and body.

#![no_main]

use libfuzzer_sys::fuzz_target;
use veloc::backend::wire;

fuzz_target!(|data: &[u8]| {
    let mut r = std::io::Cursor::new(data);
    if let Ok((header, body)) = wire::read_frame(&mut r) {
        let mut again = Vec::new();
        wire::write_frame(&mut again, &header, &body)
            .expect("a decoded frame must re-encode");
        let (h2, b2) = wire::read_frame(&mut std::io::Cursor::new(again))
            .expect("a re-encoded frame must decode");
        assert_eq!(h2, header, "header not canonical");
        assert_eq!(b2, body, "body not canonical");
    }
});
