//! Fuzz the VDLT delta-container parser (`delta/manifest.rs`).
//!
//! Invariant: `decode` returns `Ok` or a typed `ManifestError` for any
//! input; every offset computed from a declared novel-chunk length is
//! checked, so hostile lengths yield `ChunkOverrun`, never an overflow,
//! an out-of-bounds slice, or an allocation sized by the attacker. A
//! manifest that survives must round-trip through its JSON encoding.
//!
//! Most random inputs die at the whole-container CRC gate; the committed
//! corpus seeds carry *valid* CRCs so coverage reaches the header and
//! length parsing behind it (the fuzzer preserves that property often
//! enough once seeded).

#![no_main]

use libfuzzer_sys::fuzz_target;
use veloc::delta::manifest::{self, DeltaManifest};

fuzz_target!(|data: &[u8]| {
    if let Ok((m, chunks)) = manifest::decode(data) {
        let back = DeltaManifest::from_json(&m.to_json())
            .expect("a decoded manifest must re-parse from its own JSON");
        assert_eq!(back, m, "manifest JSON round-trip not canonical");
        // Every carried payload re-hashes to its fingerprint (decode
        // verified it; the invariant must survive the copy out).
        for (fp, payload) in &chunks {
            assert_eq!(veloc::delta::chunker::Fingerprint::of(payload), *fp);
        }
        // strip_payloads re-encodes the manifest without payloads; on a
        // valid container it must succeed and decode again.
        let stripped = manifest::strip_payloads(data).expect("strip after decode");
        let (m2, empty) = manifest::decode(&stripped).expect("stripped decodes");
        assert_eq!(m2, m);
        assert!(empty.is_empty());
    }
});
