//! End-to-end observability tests: a traced 4-rank multi-level wave
//! produces a well-formed span timeline (every span closed, parents
//! resolve, stages nest under the shared wave root), and the daemon's
//! embedded HTTP endpoint serves health plus a format-valid Prometheus
//! exposition covering every metric namespace the workload exercised.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::obs::{http_get, prom, wait_ready};

const SHORT: Duration = Duration::from_secs(2);

static DIRS: AtomicU64 = AtomicU64::new(0);

/// A daemon config with a unique home directory (mirrors the ipc tests).
#[cfg(unix)]
fn daemon_config(tag: &str) -> VelocConfig {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.stack.erasure_group = 0;
    cfg.backend.dir = std::env::temp_dir().join(format!(
        "veloc-obs-{tag}-{}-{}",
        std::process::id(),
        DIRS.fetch_add(1, Ordering::SeqCst)
    ));
    cfg
}

/// The acceptance gate for the span plane: a full 4-rank wave through
/// every resilience level under tracing yields a validated timeline —
/// one shared wave root, one command span per rank nested under it, a
/// capture stage and module stages labeled local/partner/erasure/pfs
/// nested under each command — and the per-stage latency histogram
/// fills alongside the spans.
#[test]
fn traced_wave_timeline_is_well_formed() {
    let mut cfg = VelocConfig::default().with_nodes(2, 2);
    cfg.obs.trace = true;
    let rt = VelocRuntime::new(cfg).unwrap();
    let clients: Vec<_> = (0..4).map(|r| rt.client(r)).collect();
    for c in &clients {
        c.mem_protect(0, vec![(c.rank() + 1) as u8; 64 << 10]);
    }
    for c in &clients {
        c.checkpoint("app", 1).unwrap();
    }
    for c in &clients {
        c.checkpoint_wait_done("app", 1).unwrap();
    }
    rt.drain();

    rt.tracer()
        .validate()
        .expect("span timeline must be well-formed");
    assert_eq!(rt.tracer().dropped(), 0);
    let spans = rt.tracer().snapshot();

    let root = spans
        .iter()
        .find(|s| s.name == "wave v1")
        .expect("collective wave root span");
    assert_eq!(root.parent, 0, "wave root must be a root span");

    // One command span per rank, all nested under the shared root.
    let cmds: Vec<_> = spans
        .iter()
        .filter(|s| s.name == "ckpt" && s.parent == root.id)
        .collect();
    assert_eq!(cmds.len(), 4, "one ckpt span per rank under the wave root");

    for cmd in &cmds {
        let children: Vec<_> = spans.iter().filter(|s| s.parent == cmd.id).collect();
        assert!(
            children.iter().any(|s| s.name == "capture"),
            "rank command must record its capture stage"
        );
        let levels: Vec<&str> = children
            .iter()
            .filter_map(|s| {
                s.labels
                    .iter()
                    .find(|(k, _)| k == "level")
                    .map(|(_, v)| v.as_str())
            })
            .collect();
        for lvl in ["local", "partner", "erasure", "pfs"] {
            assert!(
                levels.contains(&lvl),
                "rank command must cover level {lvl}: got {levels:?}"
            );
        }
    }

    // Per-stage latency histogram filled alongside the spans: one local
    // write per rank.
    let hist = rt
        .metrics()
        .histogram("ckpt.stage", &[("stage", "local"), ("level", "local")])
        .expect("ckpt.stage{stage=local,level=local} histogram");
    assert_eq!(hist.count(), 4);

    // The Chrome export carries every span with its tree metadata.
    let j = rt.tracer().to_chrome_json();
    let events = j.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), spans.len());
}

/// The Chrome trace-event export is an external contract
/// (`chrome://tracing`, Perfetto, CI tooling all parse it): pin the
/// exact schema with a golden file. Wall-clock fields (`ts`, `dur`) are
/// zeroed before comparison; everything else — key set, event phases,
/// tree metadata in `args`, label placement — must match the checked-in
/// golden byte for byte.
#[test]
fn chrome_trace_export_matches_golden_schema() {
    use veloc::obs::{SpanId, TraceRecorder};
    use veloc::util::json::Json;

    let t = TraceRecorder::new(true);
    let root = t.open("wave v1", SpanId::NONE, &[("version", "1")], 0);
    let cmd = t.open("ckpt", root, &[("level", "local"), ("rank", "0")], 3);
    t.event("cache.hit", cmd, &[("key", "app/1/0")], 3);
    t.close(cmd);
    t.close(root);

    let exported = t.to_chrome_json();
    let events = exported.get("traceEvents").unwrap().as_arr().unwrap();
    let normalized: Vec<Json> = events
        .iter()
        .map(|e| {
            let mut n = e.clone().set("ts", 0u64);
            if n.get("dur").is_some() {
                n = n.set("dur", 0u64);
            }
            n
        })
        .collect();
    let normalized = Json::obj()
        .set("displayTimeUnit", "ms")
        .set("traceEvents", Json::Arr(normalized));

    let golden_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/chrome_trace.json");
    let golden = std::fs::read_to_string(&golden_path).unwrap();
    assert_eq!(
        Json::parse(&golden).expect("golden file parses"),
        normalized,
        "chrome trace schema drifted from tests/golden/chrome_trace.json"
    );
    assert_eq!(
        normalized.to_pretty(),
        golden.trim_end(),
        "chrome trace serialization drifted from the golden file"
    );
}

/// Span-ring overflow is *surfaced*, never silent: past capacity the
/// recorder counts drops, the runtime publishes them as the
/// `obs.spans.dropped` gauge on drain, and the one-per-run warning has
/// fired (the counter is the part a test can see).
#[test]
fn span_overflow_surfaces_as_dropped_metric() {
    let mut cfg = VelocConfig::default().with_nodes(2, 2);
    cfg.obs.trace = true;
    cfg.obs.span_capacity = 16; // floor capacity: a 4-rank wave overflows
    let rt = VelocRuntime::new(cfg).unwrap();
    let clients: Vec<_> = (0..4).map(|r| rt.client(r)).collect();
    for c in &clients {
        c.mem_protect(0, vec![(c.rank() + 1) as u8; 32 << 10]);
    }
    for c in &clients {
        c.checkpoint("app", 1).unwrap();
    }
    for c in &clients {
        c.checkpoint_wait_done("app", 1).unwrap();
    }
    rt.drain();
    let dropped = rt.tracer().dropped();
    assert!(dropped > 0, "16-span ring must overflow under a 4-rank wave");
    assert_eq!(
        rt.metrics().gauge("obs.spans.dropped"),
        dropped,
        "drain must publish the drop count as a gauge"
    );
}

/// Tracing off (the default) records nothing and costs nothing, while
/// the metrics plane keeps flowing.
#[test]
fn tracing_disabled_records_nothing() {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.stack.erasure_group = 0;
    let rt = VelocRuntime::new(cfg).unwrap();
    let c = rt.client(0);
    c.mem_protect(0, vec![7u8; 16 << 10]);
    c.checkpoint("app", 1).unwrap();
    c.checkpoint_wait_done("app", 1).unwrap();
    rt.drain();

    assert!(!rt.tracer().is_enabled());
    assert!(rt.tracer().snapshot().is_empty());
    assert_eq!(rt.metrics().counter("ckpt.requests"), 1);
    let hist = rt
        .metrics()
        .histogram("ckpt.stage", &[("stage", "local"), ("level", "local")])
        .expect("stage histogram fills even with tracing off");
    assert_eq!(hist.count(), 1);
}

/// The daemon's embedded endpoint end to end: `/healthz` and `/readyz`
/// come up with the daemon, unknown paths 404, and after a real
/// workload (two checkpoint waves with aggregation + delta enabled,
/// then a restore) the `/metrics` scrape parses as Prometheus text and
/// covers every namespace the workload exercised — including labeled
/// per-job series and the bucketed stage histogram.
#[cfg(unix)]
#[test]
fn daemon_endpoint_serves_health_and_full_exposition() {
    use veloc::backend::{BackendClient, BackendDaemon};
    use veloc::pipeline::CkptStatus;

    let mut cfg = daemon_config("scrape");
    cfg.obs.http = Some("127.0.0.1:0".to_string());
    cfg.aggregation.enabled = true;
    cfg.delta.enabled = true;
    let daemon = BackendDaemon::start(cfg.clone()).unwrap();
    let server = {
        let d = std::sync::Arc::clone(&daemon);
        let handle = std::thread::spawn(move || d.serve());
        let socket = cfg.backend.socket_path();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while !socket.exists() {
            assert!(std::time::Instant::now() < deadline, "daemon never bound");
            std::thread::sleep(Duration::from_millis(10));
        }
        handle
    };
    let addr = daemon
        .obs_addr()
        .expect("obs.http configured: endpoint must be up")
        .to_string();
    wait_ready(&addr, Duration::from_secs(10)).unwrap();

    let (code, body) = http_get(&addr, "/healthz", SHORT).unwrap();
    assert_eq!((code, body.as_str()), (200, "ok\n"));
    let (code, _) = http_get(&addr, "/readyz", SHORT).unwrap();
    assert_eq!(code, 200, "journal replayed + queues accepting = ready");
    let (code, _) = http_get(&addr, "/nope", SHORT).unwrap();
    assert_eq!(code, 404);

    // Drive a workload through the daemon so every namespace has live
    // series: two waves (delta: one full + one incremental), a full
    // drain (aggregation containers), then a restore.
    let backend = BackendClient::connect(cfg.backend.socket_path());
    let client = backend.client("jobA", 0).unwrap();
    let h = client.mem_protect(0, vec![0x42; 32 << 10]);
    for v in [1u64, 2] {
        client.checkpoint("app", v).unwrap();
        let st = client.checkpoint_wait("app", v).unwrap();
        assert!(matches!(st, CkptStatus::Done(_)), "v{v}: {st:?}");
    }
    assert!(daemon.drain(Duration::from_secs(30)));
    *h.lock().unwrap() = Vec::new();
    let info = client.restart("app").unwrap().expect("restore");
    assert_eq!(info.version, 2);

    let (code, text) = http_get(&addr, "/metrics", SHORT).unwrap();
    assert_eq!(code, 200);
    let fams = prom::parse_exposition(&text).expect("format-valid exposition");
    let names: Vec<&str> = fams.iter().map(|f| f.name.as_str()).collect();
    for ns in [
        "veloc_ckpt",
        "veloc_backend",
        "veloc_agg",
        "veloc_delta",
        "veloc_restore",
        "veloc_restart",
    ] {
        assert!(
            names.iter().any(|n| n.starts_with(ns)),
            "exposition must cover the {ns} namespace: {names:?}"
        );
    }

    // Labeled per-job series survive the render/parse round-trip.
    let settled = fams
        .iter()
        .find(|f| f.name == "veloc_backend_settled")
        .expect("backend.settled family");
    assert!(
        settled
            .samples
            .iter()
            .any(|s| s.labels.iter().any(|(k, v)| k == "job" && v == "jobA")),
        "per-job settled series missing: {:?}",
        settled.samples
    );

    // The stage histogram renders as a closed bucket ladder.
    let hist = fams
        .iter()
        .find(|f| f.name == "veloc_ckpt_stage")
        .expect("ckpt.stage histogram family");
    assert_eq!(hist.typ, "histogram");
    assert!(
        hist.samples.iter().any(|s| s.name == "veloc_ckpt_stage_bucket"
            && s.labels.iter().any(|(k, v)| k == "le" && v == "+Inf")),
        "histogram must close with a +Inf bucket"
    );

    drop(client);
    backend.shutdown().unwrap();
    server.join().unwrap().unwrap();
    // The endpoint dies with the daemon.
    assert!(http_get(&addr, "/healthz", SHORT).is_err());
    let _ = std::fs::remove_dir_all(&cfg.backend.dir);
}
