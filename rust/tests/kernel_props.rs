//! Seeded property tests pinning every vectorized data-plane kernel
//! bit-for-bit against its byte-serial scalar reference, across odd
//! lengths, misaligned offsets, empty and 1-byte inputs. These are the
//! contracts that let the fast paths replace the scalars everywhere
//! without a format or boundary change.

use veloc::delta::Chunker;
use veloc::modules::{xor_into, xor_into_scalar};
use veloc::storage::{FabricConfig, StorageFabric};
use veloc::util::gf::{gf_mul_slice_scalar, gf_mul_slice_wide};
use veloc::util::kernels::{crc32_scalar, crc32_wide, fp_hash64, fp_hash64_scalar};
use veloc::util::rng::Rng;

/// The length grid every kernel is exercised on: empty, 1 byte, around
/// every word/stride boundary (8/16/32), odd primes, and a page-plus.
fn lens() -> Vec<usize> {
    let mut v = vec![0, 1, 2, 3, 7, 8, 9, 15, 16, 17, 23, 31, 32, 33];
    v.extend([63usize, 64, 65, 127, 257, 1021, 4096, 4099, 65 << 10]);
    v
}

/// Misaligned views: skip a few bytes so the kernel body never starts on
/// a word boundary.
fn offsets() -> [usize; 4] {
    [0, 1, 3, 7]
}

fn filled(rng: &mut Rng, n: usize) -> Vec<u8> {
    let mut v = vec![0u8; n];
    rng.fill_bytes(&mut v);
    v
}

#[test]
fn crc32_wide_matches_scalar_everywhere() {
    let mut rng = Rng::new(0xC12C);
    for n in lens() {
        let data = filled(&mut rng, n);
        for off in offsets() {
            if off > n {
                continue;
            }
            let view = &data[off..];
            assert_eq!(
                crc32_wide(view),
                crc32_scalar(view),
                "len {n} offset {off}"
            );
        }
    }
}

#[test]
fn fp_hash64_matches_scalar_everywhere() {
    let mut rng = Rng::new(0xF9A5);
    for n in lens() {
        let data = filled(&mut rng, n);
        for off in offsets() {
            if off > n {
                continue;
            }
            let view = &data[off..];
            assert_eq!(
                fp_hash64(view),
                fp_hash64_scalar(view),
                "len {n} offset {off}"
            );
        }
    }
}

#[test]
fn xor_into_matches_scalar_and_zero_extends() {
    let mut rng = Rng::new(0x0E0E);
    for n in lens() {
        let src = filled(&mut rng, n);
        for off in offsets() {
            if off > n {
                continue;
            }
            // Equal lengths, misaligned accumulator start.
            let base = filled(&mut rng, n);
            let mut a = base.clone();
            let mut b = base.clone();
            xor_into(&mut a[off..], &src[off..]);
            xor_into_scalar(&mut b[off..], &src[off..]);
            assert_eq!(a, b, "len {n} offset {off}");
            // Short source: the wide path must behave as if src were
            // zero-extended to the accumulator length (XOR with zero).
            let mut a = base.clone();
            let mut b = base.clone();
            let short = &src[..n / 2];
            xor_into(&mut a, short);
            xor_into_scalar(&mut b, short);
            assert_eq!(a, b, "zero-extension len {n}");
        }
    }
}

#[test]
fn gf_mul_slice_wide_matches_scalar_for_all_coefficient_classes() {
    let mut rng = Rng::new(0x6F6F);
    // 0 and 1 take shortcut paths; the rest sweep popcounts and the
    // high-bit reduction.
    for c in [0u8, 1, 2, 3, 0x1D, 0x53, 0x80, 0xFE, 0xFF] {
        for n in lens() {
            let src = filled(&mut rng, n);
            let base = filled(&mut rng, n);
            for off in offsets() {
                if off > n {
                    continue;
                }
                let mut a = base.clone();
                let mut b = base.clone();
                gf_mul_slice_wide(&mut a[off..], &src[off..], c);
                gf_mul_slice_scalar(&mut b[off..], &src[off..], c);
                assert_eq!(a, b, "c {c:#x} len {n} offset {off}");
            }
        }
    }
}

#[test]
fn gear_cut_unrolled_matches_scalar_boundaries() {
    let mut rng = Rng::new(0x9EA2);
    let ch = Chunker::new(64, 256, 1024).unwrap();
    for n in [0usize, 1, 63, 64, 65, 255, 256, 257, 1023, 1024, 1025, 64 << 10] {
        let data = filled(&mut rng, n);
        for off in offsets() {
            if off > n {
                continue;
            }
            // Every boundary along the buffer must agree, not just the
            // first: walk both cut functions to exhaustion.
            let mut da = &data[off..];
            let mut db = &data[off..];
            loop {
                assert_eq!(
                    ch.cut(da),
                    ch.cut_scalar(db),
                    "len {n} offset {off} at {} remaining",
                    da.len()
                );
                if da.is_empty() {
                    break;
                }
                let c = ch.cut(da);
                da = &da[c..];
                db = &db[c..];
            }
        }
    }
}

#[test]
fn put_gather_equals_concatenated_put() {
    let mut rng = Rng::new(0x6A7E);
    let fabric = StorageFabric::build(&FabricConfig::default()).unwrap();
    let tier = fabric.pfs();
    for (i, n) in lens().into_iter().enumerate() {
        let data = filled(&mut rng, n);
        // Split into 0..=3 uneven parts (including empty parts).
        let a = n / 3;
        let b = a + n / 4;
        let parts: Vec<&[u8]> = vec![&data[..a], &data[a..b], &data[b..]];
        let key = format!("gather.{i}");
        tier.put_gather(&key, &parts).unwrap();
        let (read, _) = tier.get(&key).unwrap();
        assert_eq!(read, data, "len {n}");
    }
}
