//! Daemon round-trip tests over the real Unix-domain-socket wire
//! protocol: register → submit (inline and staged handoff) → wait →
//! restart query, concurrent multi-client fairness, typed backpressure
//! and wait timeouts, and a crash/replay cycle across two daemon
//! incarnations sharing one socket path.
#![cfg(unix)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;
use veloc::api::{SimHooks, VelocConfig};
use veloc::backend::{BackendClient, BackendDaemon, Backpressure};
use veloc::pipeline::CkptStatus;
use veloc::storage::StorageFabric;

static DIRS: AtomicU64 = AtomicU64::new(0);

/// A daemon config with a unique home directory and a short socket path.
fn daemon_config(tag: &str) -> VelocConfig {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.stack.erasure_group = 0;
    cfg.backend.dir = std::env::temp_dir().join(format!(
        "veloc-ipc-{tag}-{}-{}",
        std::process::id(),
        DIRS.fetch_add(1, Ordering::SeqCst)
    ));
    cfg
}

/// Serve `daemon` on a background thread and wait for the socket to bind.
fn serve(daemon: &Arc<BackendDaemon>) -> std::thread::JoinHandle<anyhow::Result<()>> {
    let d = Arc::clone(daemon);
    let handle = std::thread::spawn(move || d.serve());
    let socket = daemon.backend_config().socket_path();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while !socket.exists() {
        assert!(
            std::time::Instant::now() < deadline,
            "daemon never bound {}",
            socket.display()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    handle
}

fn cleanup(cfg: &VelocConfig) {
    let _ = std::fs::remove_dir_all(&cfg.backend.dir);
}

#[test]
fn socket_round_trip_inline_and_staged() {
    let cfg = daemon_config("rt");
    let inline_max = cfg.backend.inline_max;
    let daemon = BackendDaemon::start(cfg.clone()).unwrap();
    let server = serve(&daemon);

    let backend = BackendClient::connect(cfg.backend.socket_path());
    let client = backend.client("jobA", 0).unwrap();
    // Small region: travels inline in the submit frame.
    let small = client.mem_protect(0, vec![0x11; 4 << 10]);
    // Large region: pushes the container over inline_max → staged handoff.
    let large = client.mem_protect(1, vec![0x22; inline_max + (64 << 10)]);
    for v in [1u64, 2] {
        client.checkpoint("app", v).unwrap();
        let st = client.checkpoint_wait("app", v).unwrap();
        assert!(matches!(st, CkptStatus::Done(_)), "v{v}: {st:?}");
    }
    // The staging directory holds no leftovers: staged files are adopted
    // by rename into the journal and deleted when the entry settles.
    assert!(daemon.drain(Duration::from_secs(30)));
    let staged_leftovers = std::fs::read_dir(daemon.staging_dir()).unwrap().count();
    assert_eq!(staged_leftovers, 0, "staged files must be adopted");

    // Restart query returns the exact bytes.
    *small.lock().unwrap() = Vec::new();
    *large.lock().unwrap() = Vec::new();
    let info = client.restart("app").unwrap().expect("restore");
    assert_eq!(info.version, 2);
    assert_eq!(*small.lock().unwrap(), vec![0x11; 4 << 10]);
    assert_eq!(*large.lock().unwrap(), vec![0x22; inline_max + (64 << 10)]);

    // Stats round-trip exposes the backend metrics.
    let stats = backend.stats().unwrap();
    let submits = stats
        .at(&["counters", "backend.submits"])
        .and_then(veloc::util::json::Json::as_u64)
        .unwrap_or(0);
    assert_eq!(submits, 2);

    drop(client);
    backend.shutdown().unwrap();
    server.join().unwrap().unwrap();
    cleanup(&cfg);
}

/// Satellite: two jobs share one daemon concurrently. Both jobs' full
/// wave sets settle (fair-share drain metrics: per-job dispatched and
/// settled counters match their submissions, round-robin picks observed
/// while both queues were busy) and same (name, version) pairs never
/// collide across jobs.
#[test]
fn concurrent_jobs_fair_share_without_collisions() {
    let cfg = daemon_config("fair");
    let daemon = BackendDaemon::start(cfg.clone()).unwrap();
    let server = serve(&daemon);
    let socket = cfg.backend.socket_path();
    const WAVES: u64 = 6;

    // Build both queues while dispatch is paused, so the fair scheduler
    // demonstrably alternates between two busy jobs on resume.
    daemon.pause_dispatch(true);
    let submit = |job: &'static str, fill: u8| {
        let socket = socket.clone();
        std::thread::spawn(move || -> anyhow::Result<()> {
            let backend = BackendClient::connect(socket);
            let client = backend.client(job, 0)?;
            client.mem_protect(0, vec![fill; 16 << 10]);
            for v in 1..=WAVES {
                client.checkpoint("app", v)?;
            }
            for v in 1..=WAVES {
                let st = client.checkpoint_wait("app", v)?;
                anyhow::ensure!(matches!(st, CkptStatus::Done(_)), "{job} v{v}: {st:?}");
            }
            Ok(())
        })
    };
    let ha = submit("jobA", 0xAA);
    let hb = submit("jobB", 0xBB);
    // Wait until both jobs acked everything, then release the dispatcher.
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    let m = daemon.runtime().metrics();
    while m.counter("backend.submits") < 2 * WAVES {
        assert!(std::time::Instant::now() < deadline, "submits never acked");
        std::thread::sleep(Duration::from_millis(10));
    }
    daemon.pause_dispatch(false);
    ha.join().unwrap().unwrap();
    hb.join().unwrap().unwrap();
    assert!(daemon.drain(Duration::from_secs(30)));

    // Fair-share drain metrics.
    assert_eq!(m.counter_with("backend.dispatched", &[("job", "jobA")]), WAVES);
    assert_eq!(m.counter_with("backend.dispatched", &[("job", "jobB")]), WAVES);
    assert_eq!(m.counter_with("backend.settled", &[("job", "jobA")]), WAVES);
    assert_eq!(m.counter_with("backend.settled", &[("job", "jobB")]), WAVES);
    assert!(
        m.counter("backend.fair.rr_picks") >= WAVES,
        "round-robin must alternate between two busy jobs: {} picks",
        m.counter("backend.fair.rr_picks")
    );
    assert_eq!(m.gauge_with("backend.queue_depth", &[("job", "jobA")]), 0);
    assert_eq!(m.gauge_with("backend.queue_depth", &[("job", "jobB")]), 0);

    // No cross-job version collisions: same (name, version), different
    // payloads, each restores its own.
    let backend = BackendClient::connect(&socket);
    for (job, fill) in [("jobA", 0xAAu8), ("jobB", 0xBB)] {
        let client = backend.client(job, 0).unwrap();
        let h = client.mem_protect(0, Vec::new());
        let info = client.restart_version("app", WAVES).unwrap().expect("restore");
        assert_eq!(info.version, WAVES);
        assert_eq!(*h.lock().unwrap(), vec![fill; 16 << 10], "{job} payload");
    }

    backend.shutdown().unwrap();
    server.join().unwrap().unwrap();
    cleanup(&cfg);
}

#[test]
fn backpressure_and_wait_timeout_are_typed_over_the_socket() {
    let mut cfg = daemon_config("bp");
    cfg.backend.queue_depth = 2;
    let daemon = BackendDaemon::start(cfg.clone()).unwrap();
    let server = serve(&daemon);

    let backend = BackendClient::connect(cfg.backend.socket_path())
        .with_wait_timeout(Duration::from_millis(300));
    let client = backend.client("jobA", 0).unwrap();
    client.mem_protect(0, vec![1u8; 8 << 10]);

    // Stall the drain: acks keep landing, nothing settles.
    daemon.runtime().backend().pause_background(true);
    client.checkpoint("app", 1).unwrap();
    client.checkpoint("app", 2).unwrap();
    // The wait budget expires as a typed status, not an error or a hang.
    let st = client.checkpoint_wait("app", 1).unwrap();
    assert_eq!(st, CkptStatus::TimedOut);
    // The admission window is full: typed backpressure.
    let err = client.checkpoint("app", 3).unwrap_err();
    let bp = err.downcast_ref::<Backpressure>().expect("typed backpressure");
    assert_eq!(bp.job, "jobA");

    daemon.runtime().backend().pause_background(false);
    assert!(daemon.drain(Duration::from_secs(30)));
    client.checkpoint("app", 3).unwrap();
    let st = client.checkpoint_wait("app", 3).unwrap();
    assert!(matches!(st, CkptStatus::Done(_)), "{st:?}");

    drop(client);
    backend.shutdown().unwrap();
    server.join().unwrap().unwrap();
    cleanup(&cfg);
}

/// Restart storm over the socket: eight clients cold-restore the same
/// job/version through one daemon. The restore plane's read-through cache
/// and single-flight table must collapse the redundant fetches — the
/// node-local tier serves (about) one read for the whole storm, and every
/// client still gets the exact bytes.
#[test]
fn restart_storm_collapses_tier_reads_to_one_fetch() {
    const STORM: usize = 8;
    let cfg = daemon_config("storm");
    let fabric = Arc::new(StorageFabric::build(&cfg.fabric).unwrap());
    let hooks = SimHooks {
        fabric: Some(Arc::clone(&fabric)),
        ..SimHooks::default()
    };
    let daemon = BackendDaemon::start_with_hooks(cfg.clone(), hooks).unwrap();
    let server = serve(&daemon);
    let socket = cfg.backend.socket_path();
    let payload = vec![0x3C; 32 << 10];

    // One checkpoint, fully settled, then a quiet fabric baseline.
    let backend = BackendClient::connect(&socket);
    let writer = backend.client("jobA", 0).unwrap();
    writer.mem_protect(0, payload.clone());
    writer.checkpoint("app", 1).unwrap();
    let st = writer.checkpoint_wait("app", 1).unwrap();
    assert!(matches!(st, CkptStatus::Done(_)), "{st:?}");
    assert!(daemon.drain(Duration::from_secs(30)));
    drop(writer);
    let local_reads = |fabric: &StorageFabric| -> u64 {
        fabric.local_tiers(0).iter().map(|t| t.get_count()).sum()
    };
    let reads_before = local_reads(&fabric);

    // The storm: STORM clients restore the same (job, rank, version) at
    // once, each over its own connection.
    let handles: Vec<_> = (0..STORM)
        .map(|_| {
            let socket = socket.clone();
            let expect = payload.clone();
            std::thread::spawn(move || -> anyhow::Result<()> {
                let backend = BackendClient::connect(socket);
                let client = backend.client("jobA", 0)?;
                let h = client.mem_protect(0, Vec::new());
                let info = client
                    .restart_version("app", 1)?
                    .ok_or_else(|| anyhow::anyhow!("storm restore failed"))?;
                anyhow::ensure!(info.version == 1, "restored v{}", info.version);
                anyhow::ensure!(*h.lock().unwrap() == expect, "payload mismatch");
                Ok(())
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap().unwrap();
    }

    // The tier-read counter is the proof: one fetch (two, allowing one
    // benign race) served all eight clients.
    let storm_reads = local_reads(&fabric) - reads_before;
    assert!(
        storm_reads <= 2,
        "storm of {STORM} clients cost {storm_reads} tier reads — the cache \
         and single-flight table failed to collapse them"
    );
    let m = daemon.runtime().metrics();
    assert!(
        m.counter("restore.cache.hits") + m.counter("restore.singleflight.coalesced")
            >= (STORM - 1) as u64,
        "{} hits + {} coalesced over {STORM} restores",
        m.counter("restore.cache.hits"),
        m.counter("restore.singleflight.coalesced")
    );

    backend.shutdown().unwrap();
    server.join().unwrap().unwrap();
    cleanup(&cfg);
}

/// The durability headline over the socket: a daemon killed mid-drain
/// after acking loses nothing — a second incarnation on the same home
/// directory replays the journal and serves the bytes back.
#[test]
fn daemon_crash_replay_serves_acked_checkpoint_over_socket() {
    let cfg = daemon_config("crash");
    let fabric = Arc::new(StorageFabric::build(&cfg.fabric).unwrap());
    let payload = vec![0x5A; 96 << 10]; // above inline_max: staged handoff

    {
        let hooks = SimHooks {
            fabric: Some(Arc::clone(&fabric)),
            ..SimHooks::default()
        };
        let daemon = BackendDaemon::start_with_hooks(cfg.clone(), hooks).unwrap();
        let server = serve(&daemon);
        let backend = BackendClient::connect(cfg.backend.socket_path());
        let client = backend.client("jobA", 0).unwrap();
        client.mem_protect(0, payload.clone());
        // Park the flushes, ack the checkpoint, let it dispatch, then die.
        daemon.runtime().backend().pause_background(true);
        client.checkpoint("app", 1).unwrap();
        assert!(daemon.wait_dispatched(Duration::from_secs(10)));
        daemon.crash();
        drop(client);
        // The serve loop exits on the crashed stop flag.
        server.join().unwrap().unwrap();
    }

    let hooks = SimHooks {
        fabric: Some(fabric),
        ..SimHooks::default()
    };
    let daemon = BackendDaemon::start_with_hooks(cfg.clone(), hooks).unwrap();
    assert_eq!(
        daemon.runtime().metrics().counter("backend.journal.replayed"),
        1
    );
    let server = serve(&daemon);
    assert!(daemon.drain(Duration::from_secs(30)));

    let backend = BackendClient::connect(cfg.backend.socket_path());
    let client = backend.client("jobA", 0).unwrap();
    let st = client.checkpoint_wait("app", 1).unwrap();
    assert!(matches!(st, CkptStatus::Done(_)), "replayed ack: {st:?}");
    let h = client.mem_protect(0, Vec::new());
    let info = client.restart_version("app", 1).unwrap().expect("restore");
    assert_eq!(info.version, 1);
    assert_eq!(*h.lock().unwrap(), payload);

    drop(client);
    backend.shutdown().unwrap();
    server.join().unwrap().unwrap();
    cleanup(&cfg);
}
