//! The zero-copy gate: one full capture → level-1..4 pipeline wave must
//! perform ZERO payload memcpys at the instrumented sites (`Bytes`
//! clone-outs, borrowed-slice tier puts, owned tier gets).
//!
//! This is deliberately a single `#[test]` in its own test binary: the
//! copy counter is process-global, and libtest runs tests in one process —
//! a sibling test exercising the counted paths concurrently would make
//! the zero assertion meaningless.

use veloc::api::{VelocConfig, VelocRuntime};
use veloc::util::bufpool::{payload_copies, Bytes};

#[test]
fn full_pipeline_wave_performs_zero_payload_copies() {
    // Default stack: checksum < local < partner < erasure < transfer <
    // version — every resilience level the data plane serves (compression
    // and delta produce *derived* containers, which are new data, not
    // copies; they are covered by their own tests).
    let nodes = 4usize;
    let cfg = VelocConfig::default().with_nodes(nodes, 1);
    assert_eq!(cfg.stack.erasure_group, 4, "erasure must be in the stack");
    assert!(cfg.stack.with_partner && cfg.stack.with_transfer);
    let rt = VelocRuntime::new(cfg).unwrap();

    let clients: Vec<_> = (0..nodes).map(|r| rt.client(r)).collect();
    for (r, c) in clients.iter().enumerate() {
        c.mem_protect(0, vec![r as u8 ^ 0x5A; 256 << 10]);
    }

    let before = payload_copies();
    // Submit the whole wave first: erasure waits for the group members'
    // level-1 copies, so the four pipelines must be in flight together.
    for c in &clients {
        c.checkpoint("zc", 1).unwrap();
    }
    for c in &clients {
        c.checkpoint_wait_done("zc", 1).unwrap();
    }
    rt.drain();
    let copies = payload_copies() - before;

    // The wave really ran end to end: every rank's PFS flush landed and
    // every node holds its local copy.
    for r in 0..nodes {
        assert!(
            rt.env().fabric.pfs().exists(&format!("pfs.zc.r{r}.v1")),
            "rank {r} PFS copy missing"
        );
        assert!(
            rt.env()
                .fabric
                .local_tiers(r)
                .iter()
                .any(|t| t.exists(&format!("local.zc.r{r}.v1"))),
            "rank {r} local copy missing"
        );
    }
    assert_eq!(
        copies, 0,
        "capture → local/partner/erasure/PFS must not memcpy the payload \
         ({copies} counted copies)"
    );

    // Prove the gate can fail: the counter must be live through both the
    // Bytes layer and the memory-tier borrowed-slice/owned-get paths.
    let before = payload_copies();
    let b = Bytes::copy_from_slice(&[7u8; 1024]); // counted copy-in
    let v = b.to_vec(); // counted clone-out
    assert_eq!(v.len(), 1024);
    rt.env().fabric.pfs().put("probe", &v).unwrap(); // counted (memory tier)
    let (back, _) = rt.env().fabric.pfs().get("probe").unwrap(); // counted
    assert_eq!(back, v);
    assert_eq!(
        payload_copies() - before,
        4,
        "copy counter must observe all four instrumented copies"
    );
}
