//! Aggregated asynchronous flush: end-to-end round-trip through the full
//! runtime, drain-policy behaviour, and the modeled throughput win over
//! the file-per-rank flush pattern.

use std::sync::Arc;
use std::time::Duration;
use veloc::aggregation::{AggregationConfig, Aggregator};
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::cluster::Topology;
use veloc::pipeline::LEVEL_PFS;
use veloc::storage::{FabricConfig, StorageFabric};

/// Aggregation-enabled runtime with only local + transfer + version levels
/// (partner/erasure off so the PFS containers are the sole remote copy).
fn agg_runtime(nodes: usize, rpn: usize) -> Arc<VelocRuntime> {
    let mut cfg = VelocConfig::default().with_nodes(nodes, rpn);
    cfg.stack.erasure_group = 0;
    cfg.stack.with_partner = false;
    cfg.aggregation.enabled = true;
    VelocRuntime::new(cfg).unwrap()
}

/// Deterministic per-rank payload (distinct content, not just a fill byte,
/// so a cross-rank mixup cannot pass the bit-identical check).
fn payload(rank: usize, len: usize) -> Vec<u8> {
    (0..len)
        .map(|i| ((i * (rank + 3) + rank) % 251) as u8)
        .collect()
}

#[test]
fn aggregated_restore_round_trip_survives_local_tier_loss() {
    let nodes = 4;
    let rpn = 2;
    let world = nodes * rpn;
    let rt = agg_runtime(nodes, rpn);
    for rank in 0..world {
        let client = rt.client(rank);
        client.mem_protect(0, payload(rank, 64 << 10));
        client.checkpoint("agg", 1).unwrap();
        client.checkpoint_wait_done("agg", 1).unwrap();
    }
    rt.drain();

    // One container per node group, all ranks packed.
    let report = rt.aggregator().unwrap().report();
    assert_eq!(report.containers, nodes as u64, "one container per group");
    assert_eq!(report.segments, world as u64);
    assert!(report.write_amplification() < 1.01, "headers must stay small");
    assert_eq!(rt.metrics().counter("agg.containers"), nodes as u64);

    // Kill every local tier: only the aggregated PFS containers survive.
    for node in 0..nodes {
        rt.env().fabric.fail_node(node);
    }
    for rank in 0..world {
        let client = rt.client(rank);
        let handle = client.mem_protect(0, Vec::new());
        let info = client.restart("agg").unwrap().expect("aggregated restore");
        assert_eq!(info.level, LEVEL_PFS, "rank {rank} must restore from PFS");
        assert_eq!(info.version, 1);
        assert_eq!(
            *handle.lock().unwrap(),
            payload(rank, 64 << 10),
            "rank {rank} bytes must be bit-identical"
        );
    }
}

#[test]
fn aggregated_restore_direct_recovery_path() {
    let rt = agg_runtime(2, 2);
    for rank in 0..4 {
        let client = rt.client(rank);
        client.mem_protect(0, payload(rank, 8 << 10));
        client.checkpoint("direct", 1).unwrap();
        client.checkpoint_wait_done("direct", 1).unwrap();
    }
    rt.drain();
    let restored = rt
        .recovery()
        .restore_aggregated("direct", 3, 1)
        .unwrap()
        .expect("direct aggregated restore");
    assert_eq!(restored.level, LEVEL_PFS);
    assert_eq!(restored.ckpt.region(0).unwrap().data, payload(3, 8 << 10));
}

#[test]
fn fewer_larger_pfs_writes_than_file_per_rank() {
    let rt = agg_runtime(2, 4);
    let before = rt.env().fabric.pfs().put_count();
    for rank in 0..8 {
        let client = rt.client(rank);
        client.mem_protect(0, payload(rank, 16 << 10));
        client.checkpoint("w", 1).unwrap();
        client.checkpoint_wait_done("w", 1).unwrap();
    }
    rt.drain();
    let report = rt.aggregator().unwrap().report();
    assert_eq!(report.containers, 2);
    // Data objects hitting the PFS: 2 containers (+ index + lineage
    // bookkeeping), far below the 8 of file-per-rank.
    let data_puts = report.containers;
    assert!(
        data_puts < 8,
        "aggregation must cut PFS data writes: {data_puts} vs 8"
    );
    assert!(rt.env().fabric.pfs().put_count() > before);
    assert!(
        report.mean_write_bytes() > 2.0 * (16 << 10) as f64,
        "containers must be multiples of a rank's checkpoint"
    );
}

/// The acceptance benchmark shape, as a deterministic model-time test:
/// 64 ranks x 1 MiB, aggregated drain >= 2x the file-per-rank flush
/// throughput.
#[test]
fn model_speedup_at_64_ranks_1mib_is_at_least_2x() {
    let ranks = 64usize;
    let bytes = 1usize << 20;
    let data = Arc::new(vec![0xCDu8; bytes]);

    // File-per-rank: one PFS object per rank (sequential model charges:
    // per-op latency + fair-share transfer each).
    let fabric = StorageFabric::build(&FabricConfig {
        nodes: 8,
        ..Default::default()
    })
    .unwrap();
    let mut file_per_rank = Duration::ZERO;
    for r in 0..ranks {
        let stat = fabric
            .pfs()
            .put_shared(&format!("pfs.app.r{r}.v1"), &data)
            .unwrap();
        file_per_rank += stat.modeled;
    }

    // Aggregated: groups of 8 ranks -> 8 container writes.
    let fabric = Arc::new(
        StorageFabric::build(&FabricConfig {
            nodes: 8,
            ..Default::default()
        })
        .unwrap(),
    );
    let agg = Aggregator::new(
        Topology::new(ranks, 1),
        Arc::clone(&fabric),
        AggregationConfig {
            enabled: true,
            group_ranks: 8,
            ..Default::default()
        },
        None,
        None,
    );
    let mut aggregated = Duration::ZERO;
    for r in 0..ranks {
        let stat = agg
        .submit("app", 1, r, "raw", veloc::util::bufpool::Bytes::from_arc(Arc::clone(&data)))
        .unwrap();
        aggregated += stat.modeled;
    }
    aggregated += agg.flush_all().unwrap().modeled;
    assert_eq!(agg.report().containers, 8);

    let speedup = file_per_rank.as_secs_f64() / aggregated.as_secs_f64().max(1e-12);
    assert!(
        speedup >= 2.0,
        "aggregated flush must be >= 2x faster in the PFS model: \
         file-per-rank {file_per_rank:?}, aggregated {aggregated:?} ({speedup:.1}x)"
    );
}

#[test]
fn age_threshold_drains_stale_group() {
    let fabric = Arc::new(
        StorageFabric::build(&FabricConfig {
            nodes: 2,
            ..Default::default()
        })
        .unwrap(),
    );
    let agg = Aggregator::new(
        Topology::new(2, 2),
        fabric,
        AggregationConfig {
            enabled: true,
            version_barrier: false,
            max_delay: Duration::from_millis(20),
            ..Default::default()
        },
        None,
        None,
    );
    // Half a group: below the size threshold, no barrier.
    agg.submit("app", 1, 0, "raw", veloc::util::bufpool::Bytes::from(vec![1u8; 1024]))
        .unwrap();
    assert_eq!(agg.report().containers, 0);
    std::thread::sleep(Duration::from_millis(30));
    let stat = agg.flush_aged().unwrap();
    assert_eq!(stat.containers, 1, "aged group must drain");
    assert_eq!(agg.pending_bytes(), 0);
}

#[test]
fn duplicate_version_resubmission_keeps_last_writer() {
    let rt = agg_runtime(2, 1);
    let client = rt.client(0);
    let h = client.mem_protect(0, payload(0, 4 << 10));
    client.checkpoint("dup", 1).unwrap();
    client.checkpoint_wait_done("dup", 1).unwrap();
    *h.lock().unwrap() = payload(7, 4 << 10);
    client.checkpoint("dup", 1).unwrap();
    client.checkpoint_wait_done("dup", 1).unwrap();
    rt.drain();
    for node in 0..2 {
        rt.env().fabric.fail_node(node);
    }
    let h2 = client.mem_protect(0, Vec::new());
    client.restart("dup").unwrap().expect("restore");
    assert_eq!(*h2.lock().unwrap(), payload(7, 4 << 10));
}
