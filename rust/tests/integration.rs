//! End-to-end integration tests over the full runtime: client API, module
//! pipeline, storage fabric, failure injection and multi-level recovery.

use std::sync::Arc;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::cluster::FailureScope;
use veloc::modules::TierPolicy;
use veloc::pipeline::{
    CkptStatus, EngineMode, LEVEL_ERASURE, LEVEL_LOCAL, LEVEL_PARTNER, LEVEL_PFS,
};
use veloc::util::rng::Rng;

fn runtime(nodes: usize, rpn: usize) -> Arc<VelocRuntime> {
    let mut cfg = VelocConfig::default().with_nodes(nodes, rpn);
    cfg.stack.erasure_group = if nodes % 4 == 0 { 4 } else { 0 };
    VelocRuntime::new(cfg).unwrap()
}

fn payload(rng: &mut Rng, n: usize) -> Vec<u8> {
    let mut v = vec![0u8; n];
    rng.fill_bytes(&mut v);
    v
}

/// Checkpoint all ranks collectively at `version`; returns each rank's data.
fn checkpoint_world(
    rt: &Arc<VelocRuntime>,
    name: &str,
    version: u64,
    bytes: usize,
) -> Vec<Vec<u8>> {
    let world = rt.topology().world_size();
    let mut rng = Rng::new(version * 1000 + 7);
    let datas: Vec<Vec<u8>> = (0..world).map(|_| payload(&mut rng, bytes)).collect();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let rt = Arc::clone(rt);
            let data = datas[rank].clone();
            let name = name.to_string();
            std::thread::spawn(move || {
                let client = rt.client(rank);
                client.mem_protect(0, data);
                client.checkpoint(&name, version).unwrap();
                let st = client.checkpoint_wait(&name, version).unwrap();
                assert!(matches!(st, CkptStatus::Done(_)), "rank {rank}: {st:?}");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    datas
}

/// Restore rank `rank` and return (version, level, region-0 bytes).
fn restore_rank(rt: &Arc<VelocRuntime>, name: &str, rank: usize) -> Option<(u64, u8, Vec<u8>)> {
    let client = rt.client(rank);
    let handle = client.mem_protect(0, Vec::new());
    let info = client.restart(name).unwrap()?;
    let data = handle.lock().unwrap().clone();
    Some((info.version, info.level, data))
}

#[test]
fn all_ranks_checkpoint_and_reach_pfs() {
    let rt = runtime(4, 2);
    checkpoint_world(&rt, "app", 1, 64 << 10);
    rt.drain();
    let world = rt.topology().world_size();
    for rank in 0..world {
        let info = rt
            .env()
            .registry
            .info("app", 1, rank)
            .expect("registry entry");
        assert!(
            info.levels.contains(&LEVEL_LOCAL),
            "rank {rank}: {:?}",
            info.levels
        );
        assert!(info.levels.contains(&LEVEL_PARTNER));
        assert!(info.levels.contains(&LEVEL_ERASURE));
        assert!(info.levels.contains(&LEVEL_PFS));
        assert!(info.checksum.is_some());
    }
    assert_eq!(rt.env().registry.latest_complete("app", world), Some(1));
}

#[test]
fn rank_failure_recovers_from_local() {
    let rt = runtime(4, 2);
    let datas = checkpoint_world(&rt, "app", 3, 32 << 10);
    rt.drain();
    rt.inject_failure(&FailureScope::Rank(5));
    rt.revive_all();
    let (v, level, data) = restore_rank(&rt, "app", 5).unwrap();
    assert_eq!(v, 3);
    assert_eq!(level, LEVEL_LOCAL, "rank crash should restore from local");
    assert_eq!(data, datas[5]);
}

#[test]
fn node_failure_recovers_from_partner() {
    let rt = runtime(4, 2);
    let datas = checkpoint_world(&rt, "app", 1, 32 << 10);
    rt.drain();
    rt.inject_failure(&FailureScope::Node(1)); // ranks 2,3 + local storage
    rt.revive_all();
    for rank in [2usize, 3] {
        let (v, level, data) = restore_rank(&rt, "app", rank).unwrap();
        assert_eq!(v, 1);
        assert_eq!(level, LEVEL_PARTNER, "rank {rank}");
        assert_eq!(data, datas[rank]);
    }
    // Unaffected ranks still restore locally.
    let (_, level, _) = restore_rank(&rt, "app", 0).unwrap();
    assert_eq!(level, LEVEL_LOCAL);
}

#[test]
fn partner_pair_loss_recovers_from_erasure() {
    // Partner of node n is node n+1; killing both wipes rank r's local
    // copy *and* its partner copy. Erasure groups stride 2 nodes apart
    // (8 nodes, k=4), so exactly one group member is lost -> XOR rebuild.
    let rt = runtime(8, 1);
    let datas = checkpoint_world(&rt, "app", 2, 48 << 10);
    rt.drain();
    rt.inject_failure(&FailureScope::MultiNode(vec![2, 3]));
    rt.revive_all();
    // Rank 2's partner copy lived on node 3 (also dead) -> XOR rebuild.
    let (v, level, data) = restore_rank(&rt, "app", 2).unwrap();
    assert_eq!(v, 2);
    assert_eq!(level, LEVEL_ERASURE, "rank 2 must need the erasure level");
    assert_eq!(data, datas[2], "rank 2 rebuilt bytes differ");
    // Rank 3's partner copy lives on node 4 (alive) -> partner level.
    let (v, level, data) = restore_rank(&rt, "app", 3).unwrap();
    assert_eq!(v, 2);
    assert_eq!(level, LEVEL_PARTNER, "rank 3 restores from its partner");
    assert_eq!(data, datas[3]);
}

#[test]
fn system_failure_recovers_from_pfs() {
    let rt = runtime(4, 2);
    let datas = checkpoint_world(&rt, "app", 9, 24 << 10);
    rt.drain();
    rt.inject_failure(&FailureScope::System);
    rt.revive_all();
    for rank in 0..rt.topology().world_size() {
        let (v, level, data) = restore_rank(&rt, "app", rank).unwrap();
        assert_eq!(v, 9);
        assert_eq!(level, LEVEL_PFS, "rank {rank}");
        assert_eq!(data, datas[rank]);
    }
}

#[test]
fn restores_freshest_available_version() {
    let rt = runtime(4, 1);
    checkpoint_world(&rt, "app", 1, 8 << 10);
    let d2 = checkpoint_world(&rt, "app", 2, 8 << 10);
    rt.drain();
    let (v, _, data) = restore_rank(&rt, "app", 0).unwrap();
    assert_eq!(v, 2);
    assert_eq!(data, d2[0]);
}

#[test]
fn gc_prunes_old_versions() {
    let rt = runtime(4, 1); // keep_versions = 2 (default)
    for v in 1..=4 {
        checkpoint_world(&rt, "app", v, 4 << 10);
        rt.drain();
    }
    let versions = rt.env().registry.versions("app");
    assert!(versions.contains(&4) && versions.contains(&3));
    let t = &rt.env().fabric.local_tiers(0)[0];
    assert!(!t.exists("local.app.r0.v1"));
    assert!(t.exists("local.app.r0.v4"));
}

#[test]
fn sync_engine_equivalent_results() {
    let mut cfg = VelocConfig::default().with_nodes(4, 1);
    cfg.engine_mode = EngineMode::Sync;
    cfg.stack.erasure_group = 4;
    let rt = VelocRuntime::new(cfg).unwrap();
    let datas = checkpoint_world(&rt, "s", 1, 16 << 10);
    // No drain needed: sync mode completed everything inline.
    rt.inject_failure(&FailureScope::System);
    rt.revive_all();
    let (_, level, data) = restore_rank(&rt, "s", 2).unwrap();
    assert_eq!(level, LEVEL_PFS);
    assert_eq!(data, datas[2]);
}

#[test]
fn compression_roundtrips_through_pfs() {
    let mut cfg = VelocConfig::default().with_nodes(4, 1);
    cfg.stack.with_compression = true;
    cfg.stack.erasure_group = 0;
    let rt = VelocRuntime::new(cfg).unwrap();
    let world = rt.topology().world_size();
    for rank in 0..world {
        let client = rt.client(rank);
        client.mem_protect(0, vec![42u8; 256 << 10]); // compressible
        client.checkpoint("c", 1).unwrap();
        client.checkpoint_wait_done("c", 1).unwrap();
    }
    rt.drain();
    // PFS copy must be much smaller than the raw payload.
    let pfs_used = rt.env().fabric.pfs().used_bytes();
    assert!(pfs_used < (world as u64) * (64 << 10), "pfs holds {pfs_used}");
    rt.inject_failure(&FailureScope::System);
    rt.revive_all();
    let (_, level, data) = restore_rank(&rt, "c", 1).unwrap();
    assert_eq!(level, LEVEL_PFS);
    assert_eq!(data, vec![42u8; 256 << 10]);
}

#[test]
fn kv_module_serves_restore() {
    let mut cfg = VelocConfig::default().with_nodes(4, 1);
    cfg.stack.with_kv = true;
    cfg.fabric.with_kv = true;
    cfg.stack.with_transfer = false; // KV is the only persistent level
    cfg.stack.erasure_group = 0;
    let rt = VelocRuntime::new(cfg).unwrap();
    let datas = checkpoint_world(&rt, "k", 1, 16 << 10);
    rt.drain();
    rt.inject_failure(&FailureScope::System);
    rt.revive_all();
    let (_, level, data) = restore_rank(&rt, "k", 0).unwrap();
    assert_eq!(level, veloc::pipeline::LEVEL_KV);
    assert_eq!(data, datas[0]);
}

#[test]
fn concurrency_aware_policy_still_correct() {
    let mut cfg = VelocConfig::default().with_nodes(4, 2);
    cfg.stack.tier_policy = TierPolicy::ConcurrencyAware;
    cfg.stack.erasure_group = 4;
    let rt = VelocRuntime::new(cfg).unwrap();
    let datas = checkpoint_world(&rt, "p", 1, 32 << 10);
    rt.drain();
    rt.inject_failure(&FailureScope::Rank(3));
    rt.revive_all();
    let (_, _, data) = restore_rank(&rt, "p", 3).unwrap();
    assert_eq!(data, datas[3]);
}

#[test]
fn corrupted_local_copy_falls_through_to_partner() {
    let rt = runtime(4, 1);
    let datas = checkpoint_world(&rt, "x", 1, 16 << 10);
    rt.drain();
    // Corrupt rank 0's local copy in place.
    let tier = &rt.env().fabric.local_tiers(0)[0];
    let key = "local.x.r0.v1";
    let (mut data, _) = tier.get(key).unwrap();
    let mid = data.len() / 2;
    data[mid] ^= 0xFF;
    tier.put(key, &data).unwrap();
    let (_, level, restored) = restore_rank(&rt, "x", 0).unwrap();
    assert!(level >= LEVEL_PARTNER, "level {level}");
    assert_eq!(restored, datas[0]);
}

#[test]
fn module_switch_disables_level_at_runtime() {
    let rt = runtime(4, 1);
    rt.engine(0)
        .module_named("partner")
        .unwrap()
        .switch()
        .set(false);
    checkpoint_world(&rt, "sw", 1, 8 << 10);
    rt.drain();
    let info = rt.env().registry.info("sw", 1, 0).unwrap();
    assert!(!info.levels.contains(&LEVEL_PARTNER));
    assert!(info.levels.contains(&LEVEL_PFS));
    // Other ranks unaffected.
    let info1 = rt.env().registry.info("sw", 1, 1).unwrap();
    assert!(info1.levels.contains(&LEVEL_PARTNER));
}

#[test]
fn no_checkpoint_means_no_restore() {
    let rt = runtime(4, 1);
    let client = rt.client(0);
    client.mem_protect(0, vec![1, 2, 3]);
    assert!(client.restart("never").unwrap().is_none());
}

#[test]
fn killed_rank_cannot_checkpoint() {
    let rt = runtime(4, 1);
    rt.inject_failure(&FailureScope::Rank(0));
    let client = rt.client(0);
    client.mem_protect(0, vec![0u8; 128]);
    assert!(client.checkpoint("z", 1).is_err());
}

#[test]
fn restorable_frontier_is_consistent() {
    let rt = runtime(4, 1);
    checkpoint_world(&rt, "f", 1, 8 << 10);
    checkpoint_world(&rt, "f", 2, 8 << 10);
    rt.drain();
    let frontier = rt
        .recovery()
        .restorable_frontier(rt.engines(), "f")
        .unwrap();
    assert_eq!(frontier, Some(2));
}

/// Randomized property: for any single-failure scope, every rank restores
/// bytes identical to what it checkpointed.
#[test]
fn property_single_failure_always_recovers_exact_bytes() {
    let rt = runtime(8, 1);
    let mut rng = Rng::new(2024);
    let datas = checkpoint_world(&rt, "prop", 1, 16 << 10);
    rt.drain();
    let mut datas = datas;
    let mut version = 1u64;
    for trial in 0..20 {
        let scope = match rng.below(3) {
            0 => FailureScope::Rank(rng.range_usize(0, 8)),
            1 => FailureScope::Node(rng.range_usize(0, 8)),
            _ => {
                let n = rng.range_usize(0, 8);
                FailureScope::MultiNode(vec![n, (n + 1) % 8])
            }
        };
        rt.inject_failure(&scope);
        rt.revive_all();
        for rank in 0..8 {
            let (v, _, data) = restore_rank(&rt, "prop", rank)
                .unwrap_or_else(|| panic!("trial {trial} {scope:?} rank {rank}"));
            assert_eq!(v, version);
            assert_eq!(data, datas[rank], "trial {trial} {scope:?} rank {rank}");
        }
        // Re-establish all levels for the next trial.
        version += 1;
        datas = checkpoint_world(&rt, "prop", version, 16 << 10);
        rt.drain();
    }
}

#[test]
fn cold_restart_reloads_lineage_from_persistent_pfs() {
    // Process 1: real-directory PFS, checkpoint, then drop the runtime.
    let dir = std::env::temp_dir().join(format!("veloc-cold-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mk = || {
        let mut cfg = VelocConfig::default().with_nodes(4, 1);
        cfg.stack.erasure_group = 0;
        cfg.fabric.pfs_dir = Some(dir.clone());
        VelocRuntime::new(cfg).unwrap()
    };
    let datas;
    {
        let rt1 = mk();
        datas = checkpoint_world(&rt1, "cold", 7, 16 << 10);
        rt1.drain();
    } // rt1 dropped: in-memory tiers and registry are gone.

    // Process 2: fresh runtime over the same PFS directory.
    let rt2 = mk();
    assert!(rt2.env().registry.versions("cold").is_empty());
    assert!(rt2.reload_lineage("cold").unwrap());
    assert_eq!(rt2.env().registry.versions("cold"), vec![7]);
    // Node-local copies never existed in this process: PFS serves.
    for rank in 0..4 {
        let (v, level, data) = restore_rank(&rt2, "cold", rank).unwrap();
        assert_eq!(v, 7);
        assert_eq!(level, LEVEL_PFS);
        assert_eq!(data, datas[rank], "rank {rank}");
    }
    assert!(!rt2.reload_lineage("missing").unwrap());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lineage_json_preserves_checksums() {
    let rt = runtime(4, 1);
    checkpoint_world(&rt, "lj", 1, 4 << 10);
    rt.drain();
    let reg = &rt.env().registry;
    let before = reg.info("lj", 1, 0).unwrap();
    assert!(before.checksum.is_some());
    let j = reg.to_json("lj");
    let reg2 = veloc::modules::VersionRegistry::new();
    reg2.load_json(&j).unwrap();
    let after = reg2.info("lj", 1, 0).unwrap();
    assert_eq!(after.checksum, before.checksum);
    assert_eq!(after.levels, before.levels);
    assert_eq!(after.bytes, before.bytes);
}

/// Delta mode: iterative mutations dedup across versions, and after a node
/// failure every rank — including victims whose chunk store died — restores
/// the latest version bit-for-bit through the manifest chain on a
/// surviving level.
#[test]
fn delta_checkpoints_dedup_and_restore_through_chain() {
    let mut cfg = VelocConfig::default().with_nodes(4, 1);
    cfg.stack.erasure_group = 0;
    cfg.delta.enabled = true;
    cfg.delta.min_chunk = 256;
    cfg.delta.avg_chunk = 1024;
    cfg.delta.max_chunk = 8192;
    cfg.delta.max_chain = 8;
    let rt = VelocRuntime::new(cfg).unwrap();
    let world = rt.topology().world_size();
    let mut rng = Rng::new(0xDE17A);
    let mut states: Vec<Vec<u8>> = (0..world).map(|_| payload(&mut rng, 64 << 10)).collect();
    for version in 1..=5u64 {
        for (rank, state) in states.iter_mut().enumerate() {
            // Mutate one 64-byte run per step (~0.1% of the state).
            let off = (version as usize * 997 + rank * 131) % (state.len() - 64);
            for b in &mut state[off..off + 64] {
                *b = b.wrapping_add(1);
            }
            let client = rt.client(rank);
            client.mem_protect(0, state.clone());
            client.checkpoint("dapp", version).unwrap();
            let st = client.checkpoint_wait("dapp", version).unwrap();
            assert!(matches!(st, CkptStatus::Done(_)), "rank {rank}: {st:?}");
        }
    }
    rt.drain();
    let m = rt.metrics();
    let logical = m.counter("delta.bytes.logical");
    let physical = m.counter("delta.bytes.physical");
    assert!(
        physical * 2 < logical,
        "dedup must cut physical bytes at 0.1% mutation: {physical} vs {logical}"
    );
    assert_eq!(m.counter("delta.ckpt.full"), world as u64, "one full per rank");
    rt.inject_failure(&FailureScope::Node(1));
    rt.revive_all();
    for rank in 0..world {
        let (v, _level, data) = restore_rank(&rt, "dapp", rank).unwrap();
        assert_eq!(v, 5, "rank {rank}");
        assert_eq!(data, states[rank], "rank {rank}: bit-for-bit chain restore");
    }
}

/// Delta composes with XOR erasure: a lost rank's thin containers are
/// rebuilt from the group (for the target version and its chain ancestors)
/// and reassembled bit-for-bit.
#[test]
fn delta_composes_with_erasure_rebuild() {
    let mut cfg = VelocConfig::default().with_nodes(4, 1);
    cfg.stack.with_partner = false;
    cfg.stack.with_transfer = false;
    cfg.stack.erasure_group = 4;
    cfg.delta.enabled = true;
    cfg.delta.min_chunk = 256;
    cfg.delta.avg_chunk = 1024;
    cfg.delta.max_chunk = 8192;
    cfg.delta.max_chain = 8;
    let rt = VelocRuntime::new(cfg).unwrap();
    let world = rt.topology().world_size();
    let mut rng = Rng::new(0xE7A);
    let mut states: Vec<Vec<u8>> = (0..world).map(|_| payload(&mut rng, 32 << 10)).collect();
    for version in 1..=3u64 {
        let handles: Vec<_> = (0..world)
            .map(|rank| {
                let rt = Arc::clone(&rt);
                let data = states[rank].clone();
                std::thread::spawn(move || {
                    let client = rt.client(rank);
                    client.mem_protect(0, data);
                    client.checkpoint("eapp", version).unwrap();
                    let st = client.checkpoint_wait("eapp", version).unwrap();
                    assert!(matches!(st, CkptStatus::Done(_)), "rank {rank}: {st:?}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for (rank, state) in states.iter_mut().enumerate() {
            let off = (version as usize * 769 + rank * 257) % (state.len() - 64);
            for b in &mut state[off..off + 64] {
                *b = b.wrapping_add(3);
            }
        }
    }
    rt.drain();
    rt.inject_failure(&FailureScope::Node(2));
    rt.revive_all();
    let (v, level, data) = restore_rank(&rt, "eapp", 2).unwrap();
    assert_eq!(v, 3);
    assert_eq!(level, LEVEL_ERASURE, "victim must be served by the rebuild");
    // The restored bytes are the state as checkpointed at v3 (mutations
    // after the v3 checkpoint are not part of it).
    let mut expected = states[2].clone();
    // Undo the post-checkpoint mutation of version 3 for rank 2.
    let off = (3usize * 769 + 2 * 257) % (expected.len() - 64);
    for b in &mut expected[off..off + 64] {
        *b = b.wrapping_sub(3);
    }
    assert_eq!(data, expected, "bit-for-bit erasure chain restore");
}
