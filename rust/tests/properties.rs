//! Randomized property tests on coordinator invariants (proptest is not
//! available offline; we drive the same shrink-free random exploration
//! with the deterministic xoshiro PRNG — failures print the seed).

use veloc::cluster::Topology;
use veloc::modules::{xor_fold, XorBackend};
use veloc::util::bytes::Checkpoint;
use veloc::util::json::Json;
use veloc::util::rng::Rng;

/// VCKP decode(encode(x)) == x for arbitrary region sets, and the encode
/// is deterministic (the recovery checksum validation relies on it).
#[test]
fn prop_vckp_roundtrip_and_deterministic() {
    let mut rng = Rng::new(0xC0FFEE);
    for trial in 0..200 {
        let n_regions = rng.range_usize(0, 6);
        let mut c = Checkpoint::new(
            &format!("n{}", rng.below(5)),
            rng.range_usize(0, 64),
            rng.next_u64() % 1_000_000,
        );
        for _ in 0..n_regions {
            let len = rng.range_usize(0, 4096);
            let mut data = vec![0u8; len];
            rng.fill_bytes(&mut data);
            c.push_region(rng.next_u64() as u32, data);
        }
        let enc1 = c.encode();
        let enc2 = c.encode();
        assert_eq!(enc1, enc2, "trial {trial}: encode not deterministic");
        let d = Checkpoint::decode(&enc1).unwrap();
        assert_eq!(d, c, "trial {trial}");
        let enc3 = d.encode();
        assert_eq!(enc1, enc3, "trial {trial}: re-encode differs");
    }
}

/// Any single corrupted byte in a VCKP container is detected.
#[test]
fn prop_vckp_crc_catches_any_single_corruption() {
    let mut rng = Rng::new(0xBEEF);
    let mut c = Checkpoint::new("x", 1, 2);
    let mut data = vec![0u8; 2048];
    rng.fill_bytes(&mut data);
    c.push_region(0, data);
    let enc = c.encode();
    for _ in 0..300 {
        let pos = rng.range_usize(0, enc.len());
        let bit = 1u8 << rng.below(8);
        let mut bad = enc.clone();
        bad[pos] ^= bit;
        assert!(
            Checkpoint::decode(&bad).is_err(),
            "corruption at byte {pos} bit {bit} undetected"
        );
    }
}

/// XOR backends agree on arbitrary shapes, and parity reconstructs any
/// erased buffer.
#[test]
fn prop_xor_backends_agree_and_reconstruct() {
    let mut rng = Rng::new(0xAB);
    for trial in 0..60 {
        let k = rng.range_usize(2, 9);
        let len = rng.range_usize(1, 20_000);
        let bufs: Vec<Vec<u8>> = (0..k)
            .map(|_| {
                let mut b = vec![0u8; len];
                rng.fill_bytes(&mut b);
                b
            })
            .collect();
        let refs: Vec<&[u8]> = bufs.iter().map(|b| b.as_slice()).collect();
        let scalar = xor_fold(&refs, &XorBackend::NativeScalar).unwrap();
        let wide = xor_fold(&refs, &XorBackend::NativeWide).unwrap();
        assert_eq!(scalar, wide, "trial {trial} k={k} len={len}");
        // Erase buffer e; parity ^ others == erased.
        let e = rng.range_usize(0, k);
        let mut pieces: Vec<&[u8]> = vec![&scalar];
        for (i, b) in bufs.iter().enumerate() {
            if i != e {
                pieces.push(b);
            }
        }
        let rebuilt = xor_fold(&pieces, &XorBackend::NativeWide).unwrap();
        assert_eq!(rebuilt, bufs[e], "trial {trial} erase {e}");
    }
}

/// Topology invariants for arbitrary shapes: partner bijectivity on a
/// different node; erasure groups are consistent partitions with
/// node-disjoint members.
#[test]
fn prop_topology_invariants() {
    let mut rng = Rng::new(0x7070);
    for _ in 0..100 {
        let nodes = rng.range_usize(2, 17);
        let rpn = rng.range_usize(1, 5);
        let t = Topology::new(nodes, rpn);
        let world = t.world_size();
        // Partner is a bijection with distinct node.
        let mut seen = vec![false; world];
        for r in 0..world {
            let p = t.partner_of(r);
            assert!(!seen[p], "partner collision");
            seen[p] = true;
            assert_ne!(t.node_of(r), t.node_of(p));
            assert_eq!(t.partner_source(p), r);
        }
        // Erasure groups for every divisor group size.
        for g in 2..=nodes {
            if nodes % g != 0 {
                continue;
            }
            for r in 0..world {
                let grp = t.erasure_group(r, g);
                assert_eq!(grp.len(), g);
                assert!(grp.contains(&r));
                let distinct_nodes: std::collections::BTreeSet<_> =
                    grp.iter().map(|&m| t.node_of(m)).collect();
                assert_eq!(distinct_nodes.len(), g, "group members share nodes");
                for &m in &grp {
                    assert_eq!(t.erasure_group(m, g), grp, "inconsistent group");
                }
            }
        }
    }
}

/// JSON roundtrip for arbitrary generated documents.
#[test]
fn prop_json_roundtrip() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => {
                // Limit magnitude so f64 formatting roundtrips exactly.
                Json::Num((rng.next_u64() % (1u64 << 50)) as f64 - (1u64 << 49) as f64)
            }
            3 => {
                let len = rng.range_usize(0, 12);
                let s: String = (0..len)
                    .map(|_| {
                        let c = rng.below(128) as u8;
                        if c.is_ascii_graphic() || c == b' ' {
                            c as char
                        } else {
                            '\\'
                        }
                    })
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr((0..rng.range_usize(0, 5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.range_usize(0, 5) {
                    m.insert(format!("k{i}"), gen(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    let mut rng = Rng::new(0x15);
    for trial in 0..300 {
        let doc = gen(&mut rng, 3);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("trial {trial}: {e}\n{text}"));
        assert_eq!(doc, back, "trial {trial}");
        let pretty = Json::parse(&doc.to_pretty()).unwrap();
        assert_eq!(doc, pretty, "trial {trial} (pretty)");
    }
}

/// `FailureScope::min_level` is monotone under scope widening
/// (Rank ⊂ Node ⊂ MultiNode ⊂ System) for random topologies: a wider
/// blast radius never needs a *lighter* resilience level, and the
/// affected rank/node sets grow along the chain.
#[test]
fn prop_min_level_monotone_under_scope_widening() {
    use std::collections::BTreeSet;
    use veloc::cluster::{FailureInjector, FailureScope};
    let mut rng = Rng::new(0x111E7);
    for trial in 0..200 {
        let nodes = rng.range_usize(2, 12);
        let rpn = rng.range_usize(1, 5);
        let t = Topology::new(nodes, rpn);
        let inj = FailureInjector::new(t, 100.0);
        let r = rng.range_usize(0, t.world_size());
        let node = t.node_of(r);
        // Widening chain anchored at a random rank.
        let chain = [
            FailureScope::Rank(r),
            FailureScope::Node(node),
            FailureScope::MultiNode(vec![node, (node + 1) % nodes]),
            FailureScope::System,
        ];
        for w in chain.windows(2) {
            assert!(
                w[0].min_level() <= w[1].min_level(),
                "trial {trial}: min_level({:?}) > min_level({:?})",
                w[0],
                w[1]
            );
            let narrow: BTreeSet<usize> =
                inj.affected_ranks(&w[0]).into_iter().collect();
            let wide: BTreeSet<usize> =
                inj.affected_ranks(&w[1]).into_iter().collect();
            assert!(
                narrow.is_subset(&wide),
                "trial {trial}: affected ranks of {:?} not within {:?}",
                w[0],
                w[1]
            );
            let narrow_nodes: BTreeSet<usize> =
                inj.affected_nodes(&w[0]).into_iter().collect();
            let wide_nodes: BTreeSet<usize> =
                inj.affected_nodes(&w[1]).into_iter().collect();
            assert!(
                narrow_nodes.is_subset(&wide_nodes),
                "trial {trial}: affected nodes of {:?} not within {:?}",
                w[0],
                w[1]
            );
        }
        // And min_level itself spans exactly the four levels, in order.
        assert_eq!(
            chain.iter().map(|s| s.min_level()).collect::<Vec<_>>(),
            vec![1, 2, 3, 4],
            "trial {trial}"
        );
    }
}

/// Failure schedules: events sorted, scopes valid for the topology.
#[test]
fn prop_failure_schedules_valid() {
    use veloc::cluster::{FailureInjector, FailureScope};
    let mut rng = Rng::new(0xF417);
    for _ in 0..30 {
        let nodes = rng.range_usize(2, 12);
        let rpn = rng.range_usize(1, 4);
        let t = Topology::new(nodes, rpn);
        let inj = FailureInjector::new(t, rng.range_f64(50.0, 5000.0));
        let mut srng = rng.fork(1);
        let events = inj.schedule(&mut srng, 20_000.0);
        let mut prev = 0.0;
        for e in &events {
            assert!(e.at >= prev);
            prev = e.at;
            match &e.scope {
                FailureScope::Rank(r) => assert!(*r < t.world_size()),
                FailureScope::Node(n) => assert!(*n < nodes),
                FailureScope::MultiNode(ns) => {
                    assert!(!ns.is_empty());
                    assert!(ns.iter().all(|n| *n < nodes));
                }
                FailureScope::System => {}
            }
            let affected = inj.affected_ranks(&e.scope);
            assert!(!affected.is_empty());
            assert!(affected.iter().all(|r| *r < t.world_size()));
        }
    }
}

/// Content-defined chunking: chunk → reassemble is the identity for
/// arbitrary buffers (including empty, sub-minimum and multi-max sizes),
/// and every non-final chunk respects the size bounds.
#[test]
fn prop_cdc_chunk_reassemble_identity() {
    use veloc::delta::Chunker;
    let mut rng = Rng::new(0xCDC1);
    let c = Chunker::new(64, 256, 1024).unwrap();
    for trial in 0..120 {
        let len = match trial % 4 {
            0 => rng.range_usize(0, 64),
            1 => rng.range_usize(64, 2048),
            _ => rng.range_usize(2048, 100_000),
        };
        let mut data = vec![0u8; len];
        rng.fill_bytes(&mut data);
        let chunks = c.split(&data);
        let rebuilt: Vec<u8> = chunks.concat();
        assert_eq!(rebuilt, data, "trial {trial} len {len}");
        for (i, ch) in chunks.iter().enumerate() {
            assert!(ch.len() <= 1024, "trial {trial}: chunk {i} too big");
            if i + 1 < chunks.len() {
                assert!(ch.len() > 64, "trial {trial}: non-final chunk {i} too small");
            }
        }
    }
}

/// Boundary stability: an in-place single-byte edit invalidates only O(1)
/// chunks — the fingerprint streams re-synchronize shortly after the edit
/// instead of cascading to the end of the buffer.
#[test]
fn prop_cdc_single_byte_edit_invalidates_o1_chunks() {
    use std::collections::BTreeMap;
    use veloc::delta::{Chunker, Fingerprint};
    let mut rng = Rng::new(0xED17);
    let c = Chunker::new(256, 1024, 4096).unwrap();
    let fp_counts = |chunks: &[&[u8]]| -> BTreeMap<u128, usize> {
        let mut m = BTreeMap::new();
        for ch in chunks {
            *m.entry(Fingerprint::of(ch).0).or_insert(0) += 1;
        }
        m
    };
    for trial in 0..50 {
        let mut data = vec![0u8; 64 << 10];
        rng.fill_bytes(&mut data);
        let before = fp_counts(&c.split(&data));
        let pos = rng.range_usize(0, data.len());
        data[pos] ^= 1 << rng.below(8);
        let after = fp_counts(&c.split(&data));
        // Multiset difference: chunks present in `after` but not covered
        // by `before` (and vice versa).
        let diff = |a: &BTreeMap<u128, usize>, b: &BTreeMap<u128, usize>| -> usize {
            a.iter()
                .map(|(fp, n)| n.saturating_sub(*b.get(fp).unwrap_or(&0)))
                .sum()
        };
        let invalidated = diff(&after, &before).max(diff(&before, &after));
        let total = after.values().sum::<usize>();
        assert!(
            invalidated <= 12,
            "trial {trial}: edit at {pos} invalidated {invalidated} of {total} chunks"
        );
        assert!(total > 40, "trial {trial}: expected ~64 chunks, got {total}");
    }
}

/// End-to-end delta identity: a chain of incrementally mutated checkpoints
/// encoded through `DeltaState` reassembles the final version bit-for-bit,
/// both through the manifest chain and through the chunk store alone.
#[test]
fn prop_delta_chain_roundtrip_is_identity() {
    use std::collections::BTreeMap;
    use veloc::delta::{materialize, DeltaConfig, DeltaState};
    use veloc::storage::{FabricConfig, StorageFabric};

    let mut rng = Rng::new(0xD17A);
    for trial in 0..10 {
        let fabric = StorageFabric::build(&FabricConfig {
            nodes: 1,
            ..Default::default()
        })
        .unwrap();
        let cfg = DeltaConfig {
            enabled: true,
            min_chunk: 64,
            avg_chunk: 256,
            max_chunk: 1024,
            max_chain: rng.range_usize(1, 5) as u64,
        };
        let state = DeltaState::new(cfg, &fabric, None).unwrap();
        let regions = rng.range_usize(1, 4);
        let mut datas: Vec<Vec<u8>> = (0..regions)
            .map(|_| {
                let mut d = vec![0u8; rng.range_usize(256, 16_384)];
                rng.fill_bytes(&mut d);
                d
            })
            .collect();
        let mut containers: BTreeMap<u64, Vec<u8>> = BTreeMap::new();
        let mut expected = None;
        let versions = rng.range_usize(2, 8) as u64;
        for v in 1..=versions {
            // Mutate a random slice of a random region.
            let r = rng.range_usize(0, regions);
            let len = datas[r].len();
            let off = rng.range_usize(0, len.saturating_sub(16).max(1));
            let end = (off + 16).min(len);
            for b in &mut datas[r][off..end] {
                *b = b.wrapping_add(1);
            }
            let mut ckpt = Checkpoint::new("prop", 0, v);
            for (id, d) in datas.iter().enumerate() {
                ckpt.push_region(id as u32, d.clone());
            }
            containers.insert(v, state.encode_checkpoint(&ckpt, v, 0, &|_| true).unwrap());
            expected = Some(ckpt);
        }
        let expected = expected.unwrap();
        let fetch = |v: u64| containers.get(&v).cloned();
        let via_chain = materialize(containers[&versions].clone(), None, &fetch).unwrap();
        assert_eq!(via_chain, expected, "trial {trial}: chain reassembly");
        assert_eq!(
            via_chain.encode(),
            expected.encode(),
            "trial {trial}: re-encode must be byte-identical"
        );
        let via_store = materialize(
            containers[&versions].clone(),
            Some(state.store(0).as_ref()),
            &|_| None,
        )
        .unwrap();
        assert_eq!(via_store, expected, "trial {trial}: store reassembly");
    }
}
