//! Integration tests of the AOT kernel path: full VeloC runtime with
//! `use_kernels = true` (erasure XOR + checksum through PJRT), DNN trainer
//! end-to-end, and ML interval optimizer training through PJRT.
//!
//! These tests need `make artifacts`; they self-skip otherwise.

use std::sync::Arc;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::app::{CaptureMode, DnnTrainer};
use veloc::cluster::FailureScope;
use veloc::interval::{dataset, NnOptimizer};
use veloc::pipeline::{CkptStatus, LEVEL_ERASURE};
use veloc::runtime::{default_artifacts_dir, PjrtEngine};

fn have_artifacts() -> bool {
    let ok = default_artifacts_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn kernel_runtime(nodes: usize) -> Arc<VelocRuntime> {
    let mut cfg = VelocConfig::default().with_nodes(nodes, 1);
    cfg.use_kernels = true;
    cfg.stack.use_kernels = true;
    cfg.stack.erasure_group = 4;
    VelocRuntime::new(cfg).unwrap()
}

/// Kernel runtime without the group-collective erasure level — for
/// single-client scenarios (only one rank checkpoints, so erasure's
/// group barrier would just time out in the pipeline tail).
fn solo_kernel_runtime(nodes: usize) -> Arc<VelocRuntime> {
    let mut cfg = VelocConfig::default().with_nodes(nodes, 1);
    cfg.use_kernels = true;
    cfg.stack.use_kernels = true;
    cfg.stack.erasure_group = 0;
    VelocRuntime::new(cfg).unwrap()
}

#[test]
fn kernel_erasure_rebuild_matches_bytes() {
    if !have_artifacts() {
        return;
    }
    let rt = kernel_runtime(8);
    let world = rt.topology().world_size();
    let mut datas = Vec::new();
    let handles: Vec<_> = (0..world)
        .map(|rank| {
            let rt = Arc::clone(&rt);
            std::thread::spawn(move || {
                let client = rt.client(rank);
                let data = vec![rank as u8 ^ 0x5A; 96 << 10];
                client.mem_protect(0, data.clone());
                client.checkpoint("kx", 1).unwrap();
                let st = client.checkpoint_wait("kx", 1).unwrap();
                assert!(matches!(st, CkptStatus::Done(_)));
                data
            })
        })
        .collect();
    for h in handles {
        datas.push(h.join().unwrap());
    }
    rt.drain();
    // Kill an adjacent node pair: rank 4's partner copy (on node 5) dies
    // with it, so only the kernel-backed erasure rebuild can serve rank 4.
    rt.inject_failure(&FailureScope::MultiNode(vec![4, 5]));
    rt.revive_all();
    let client = rt.client(4);
    let handle = client.mem_protect(0, Vec::new());
    let info = client.restart("kx").unwrap().expect("erasure restore");
    assert_eq!(info.level, LEVEL_ERASURE);
    assert_eq!(*handle.lock().unwrap(), datas[4]);
    // Rank 5 recovers too (partner copy on surviving node 6).
    let client5 = rt.client(5);
    let handle5 = client5.mem_protect(0, Vec::new());
    client5.restart("kx").unwrap().expect("restore");
    assert_eq!(*handle5.lock().unwrap(), datas[5]);
}

#[test]
fn kernel_checksum_validates_and_rejects() {
    if !have_artifacts() {
        return;
    }
    let rt = solo_kernel_runtime(4);
    let client = rt.client(0);
    client.mem_protect(0, vec![9u8; 32 << 10]);
    client.checkpoint("kc", 1).unwrap();
    client.checkpoint_wait_done("kc", 1).unwrap();
    rt.drain();
    // Registry carries a kernel digest.
    let info = rt.env().registry.info("kc", 1, 0).unwrap();
    assert!(info.checksum.is_some());
    // Restart validates against it.
    let handle = client.mem_protect(0, Vec::new());
    assert!(client.restart("kc").unwrap().is_some());
    assert_eq!(*handle.lock().unwrap(), vec![9u8; 32 << 10]);
}

#[test]
fn dnn_trainer_learns_and_survives_failure() {
    if !have_artifacts() {
        return;
    }
    let rt = solo_kernel_runtime(4);
    let engine = PjrtEngine::load(&default_artifacts_dir()).unwrap();
    let client = rt.client(0);
    let mut trainer = DnnTrainer::new(
        &client,
        Arc::clone(&engine),
        "dnn",
        0.05,
        CaptureMode::FineGrained,
        3,
    )
    .unwrap();
    assert!(trainer.param_count() > 500_000);
    let mut first = f32::NAN;
    let mut at_ckpt = f32::NAN;
    for i in 0..30 {
        let loss = trainer.train_step().unwrap();
        if i == 0 {
            first = loss;
        }
        at_ckpt = loss;
    }
    let v = trainer.checkpoint(&client).unwrap();
    client.checkpoint_wait_done("dnn", v).unwrap();
    rt.drain();
    assert!(at_ckpt < first, "training must learn: {first} -> {at_ckpt}");

    // Node failure; restore into a fresh trainer (fresh process model).
    rt.inject_failure(&FailureScope::Node(0));
    rt.revive_all();
    let client2 = rt.client(0);
    let mut t2 = DnnTrainer::new(
        &client2,
        Arc::clone(&engine),
        "dnn",
        0.05,
        CaptureMode::FineGrained,
        3,
    )
    .unwrap();
    let restored = t2.restart(&client2).unwrap().expect("restart");
    assert_eq!(restored, 30);
    assert_eq!(t2.step, 30);
    // Restored parameters keep the learned loss (same data stream seed,
    // so the next losses continue from the checkpointed regime).
    let next = t2.train_step().unwrap();
    assert!(
        next < first * 0.8,
        "restored model should not regress to init: {next} vs {first}"
    );
}

#[test]
fn monolithic_capture_equivalent_contents() {
    if !have_artifacts() {
        return;
    }
    let rt = solo_kernel_runtime(4);
    let engine = PjrtEngine::load(&default_artifacts_dir()).unwrap();
    let client = rt.client(0);
    let mut trainer = DnnTrainer::new(
        &client,
        engine,
        "mono",
        0.05,
        CaptureMode::Monolithic,
        3,
    )
    .unwrap();
    for _ in 0..3 {
        trainer.train_step().unwrap();
    }
    let v = trainer.checkpoint(&client).unwrap();
    client.checkpoint_wait_done("mono", v).unwrap();
    rt.drain();
    let info = rt.env().registry.info("mono", v, 0).unwrap();
    assert!(info.bytes > 2_000_000, "all tensors captured: {}", info.bytes);
}

#[test]
fn nn_interval_optimizer_trains_through_pjrt() {
    if !have_artifacts() {
        return;
    }
    let engine = PjrtEngine::load(&default_artifacts_dir()).unwrap();
    let mut nn = NnOptimizer::new(engine).unwrap();
    let data = dataset::generate(48, 6, 2, 5);
    let hist = nn.fit(&data, 60, 0.02, 9).unwrap();
    assert!(
        hist.last().unwrap() < &(hist[0] * 0.8),
        "NN loss must fall: {:?} -> {:?}",
        hist.first(),
        hist.last()
    );
    let mae = nn.mae(&data).unwrap();
    assert!(mae < 1.0, "train MAE in log10 space too big: {mae}");
    // Prediction is a usable interval.
    let w = nn.predict_interval(&data[0].features).unwrap();
    assert!(w.is_finite() && w > 0.5 && w < 1e6, "{w}");
}
