//! Edge-case and failure-injection paths: checksum rejection, capacity
//! fallback, explicit-version restore, missing-level degradation, wait
//! semantics.

use std::sync::Arc;
use std::time::Duration;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::cluster::FailureScope;
use veloc::pipeline::{LEVEL_LOCAL, LEVEL_PFS};

fn ckpt_all(rt: &Arc<VelocRuntime>, name: &str, v: u64, bytes: usize) {
    for rank in 0..rt.topology().world_size() {
        let client = rt.client(rank);
        client.mem_protect(0, vec![(rank as u8) ^ (v as u8); bytes]);
        client.checkpoint(name, v).unwrap();
        client.checkpoint_wait(name, v).unwrap();
    }
    rt.drain();
}

#[test]
fn tampered_checksum_rejects_every_copy_of_that_version() {
    let mut cfg = VelocConfig::default().with_nodes(4, 1);
    cfg.stack.erasure_group = 0;
    let rt = VelocRuntime::new(cfg).unwrap();
    ckpt_all(&rt, "t", 1, 8 << 10);
    ckpt_all(&rt, "t", 2, 8 << 10);
    // Corrupt the *registry digest* of v2 for rank 0: every stored copy of
    // v2 now fails validation, so restart falls back to v1.
    rt.env().registry.set_checksum("t", 2, 0, 0xBAD0BAD);
    let client = rt.client(0);
    client.mem_protect(0, Vec::new());
    let info = client.restart("t").unwrap().unwrap();
    assert_eq!(info.version, 1, "must fall back to the older valid version");
    // Other ranks still restore v2.
    let c1 = rt.client(1);
    c1.mem_protect(0, Vec::new());
    assert_eq!(c1.restart("t").unwrap().unwrap().version, 2);
}

#[test]
fn restart_version_pins_older_checkpoint() {
    let mut cfg = VelocConfig::default().with_nodes(4, 1);
    cfg.stack.erasure_group = 0;
    cfg.stack.keep_versions = 4;
    let rt = VelocRuntime::new(cfg).unwrap();
    for v in 1..=3 {
        ckpt_all(&rt, "pin", v, 4 << 10);
    }
    let client = rt.client(2);
    let h = client.mem_protect(0, Vec::new());
    let info = client.restart_version("pin", 2).unwrap().unwrap();
    assert_eq!(info.version, 2);
    assert_eq!(*h.lock().unwrap(), vec![2u8 ^ 2u8; 4 << 10]);
    // Nonexistent version: None, and regions untouched.
    assert!(client.restart_version("pin", 99).unwrap().is_none());
}

#[test]
fn dram_exhaustion_falls_back_to_next_local_tier() {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.fabric.dram_capacity = 16 << 10; // tiny staging area
    cfg.stack.erasure_group = 0;
    cfg.stack.with_partner = false;
    let rt = VelocRuntime::new(cfg).unwrap();
    let client = rt.client(0);
    client.mem_protect(0, vec![7u8; 64 << 10]); // > DRAM capacity
    client.checkpoint("big", 1).unwrap();
    client.checkpoint_wait("big", 1).unwrap();
    rt.drain();
    // Landed on NVMe, not DRAM.
    let tiers = rt.env().fabric.local_tiers(0);
    assert_eq!(tiers[0].used_bytes(), 0, "dram must be skipped");
    assert!(tiers[1].used_bytes() > 0, "nvme holds the copy");
    // And restores fine.
    let h = client.mem_protect(0, Vec::new());
    let info = client.restart("big").unwrap().unwrap();
    assert_eq!(info.level, LEVEL_LOCAL);
    assert_eq!(h.lock().unwrap().len(), 64 << 10);
}

#[test]
fn without_erasure_partner_pair_loss_degrades_to_pfs() {
    let mut cfg = VelocConfig::default().with_nodes(8, 1);
    cfg.stack.erasure_group = 0; // no erasure level
    let rt = VelocRuntime::new(cfg).unwrap();
    ckpt_all(&rt, "deg", 1, 8 << 10);
    rt.inject_failure(&FailureScope::MultiNode(vec![2, 3]));
    rt.revive_all();
    // Rank 2 lost local + partner; with no erasure only the PFS serves.
    let client = rt.client(2);
    client.mem_protect(0, Vec::new());
    let info = client.restart("deg").unwrap().unwrap();
    assert_eq!(info.level, LEVEL_PFS);
}

#[test]
fn wait_times_out_for_unknown_checkpoint() {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.wait_timeout = Duration::from_millis(50);
    let rt = VelocRuntime::new(cfg).unwrap();
    let client = rt.client(0);
    let err = client.checkpoint_wait("never", 1).unwrap_err().to_string();
    assert!(err.contains("timeout"), "{err}");
}

#[test]
fn duplicate_version_overwrites_cleanly() {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.stack.erasure_group = 0;
    let rt = VelocRuntime::new(cfg).unwrap();
    let client = rt.client(0);
    let h = client.mem_protect(0, vec![1u8; 4 << 10]);
    client.checkpoint("dup", 1).unwrap();
    client.checkpoint_wait("dup", 1).unwrap();
    *h.lock().unwrap() = vec![2u8; 4 << 10];
    client.checkpoint("dup", 1).unwrap(); // same version again
    client.checkpoint_wait("dup", 1).unwrap();
    rt.drain();
    let h2 = client.mem_protect(0, Vec::new());
    client.restart("dup").unwrap().unwrap();
    assert_eq!(*h2.lock().unwrap(), vec![2u8; 4 << 10]);
}

#[test]
fn unprotected_region_ids_ignored_on_restore() {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.stack.erasure_group = 0;
    let rt = VelocRuntime::new(cfg).unwrap();
    let client = rt.client(0);
    client.mem_protect(0, vec![1u8; 128]);
    client.mem_protect(7, vec![2u8; 128]);
    client.checkpoint("r", 1).unwrap();
    client.checkpoint_wait("r", 1).unwrap();
    rt.drain();
    // New client protects only region 7: restore fills it, skips 0.
    let c2 = rt.client(0);
    let h7 = c2.mem_protect(7, Vec::new());
    let info = c2.restart("r").unwrap().unwrap();
    assert_eq!(info.version, 1);
    assert_eq!(*h7.lock().unwrap(), vec![2u8; 128]);
}

#[test]
fn mem_unprotect_removes_region_from_next_checkpoint() {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.stack.erasure_group = 0;
    let rt = VelocRuntime::new(cfg).unwrap();
    let client = rt.client(0);
    client.mem_protect(0, vec![1u8; 64]);
    client.mem_protect(1, vec![2u8; 64]);
    assert_eq!(client.protected_bytes(), 128);
    client.mem_unprotect(1);
    assert_eq!(client.protected_bytes(), 64);
    client.checkpoint("u", 1).unwrap();
    client.checkpoint_wait("u", 1).unwrap();
    rt.drain();
    assert_eq!(
        rt.env().registry.info("u", 1, 0).unwrap().bytes,
        64,
        "dropped region must not be captured"
    );
}
