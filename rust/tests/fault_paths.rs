//! Edge-case and failure-injection paths: checksum rejection, capacity
//! fallback, explicit-version restore, missing-level degradation, wait
//! semantics, and aggregated-container damage (truncation, index
//! corruption, index loss).

use std::sync::Arc;
use std::time::Duration;
use veloc::aggregation::{container, Aggregator, INDEX_KEY};
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::cluster::FailureScope;
use veloc::pipeline::{LEVEL_LOCAL, LEVEL_PFS};

fn ckpt_all(rt: &Arc<VelocRuntime>, name: &str, v: u64, bytes: usize) {
    for rank in 0..rt.topology().world_size() {
        let client = rt.client(rank);
        client.mem_protect(0, vec![(rank as u8) ^ (v as u8); bytes]);
        client.checkpoint(name, v).unwrap();
        client.checkpoint_wait_done(name, v).unwrap();
    }
    rt.drain();
}

#[test]
fn tampered_checksum_rejects_every_copy_of_that_version() {
    let mut cfg = VelocConfig::default().with_nodes(4, 1);
    cfg.stack.erasure_group = 0;
    let rt = VelocRuntime::new(cfg).unwrap();
    ckpt_all(&rt, "t", 1, 8 << 10);
    ckpt_all(&rt, "t", 2, 8 << 10);
    // Corrupt the *registry digest* of v2 for rank 0: every stored copy of
    // v2 now fails validation, so restart falls back to v1.
    rt.env().registry.set_checksum("t", 2, 0, 0xBAD0BAD);
    let client = rt.client(0);
    client.mem_protect(0, Vec::new());
    let info = client.restart("t").unwrap().unwrap();
    assert_eq!(info.version, 1, "must fall back to the older valid version");
    // Other ranks still restore v2.
    let c1 = rt.client(1);
    c1.mem_protect(0, Vec::new());
    assert_eq!(c1.restart("t").unwrap().unwrap().version, 2);
}

#[test]
fn restart_version_pins_older_checkpoint() {
    let mut cfg = VelocConfig::default().with_nodes(4, 1);
    cfg.stack.erasure_group = 0;
    cfg.stack.keep_versions = 4;
    let rt = VelocRuntime::new(cfg).unwrap();
    for v in 1..=3 {
        ckpt_all(&rt, "pin", v, 4 << 10);
    }
    let client = rt.client(2);
    let h = client.mem_protect(0, Vec::new());
    let info = client.restart_version("pin", 2).unwrap().unwrap();
    assert_eq!(info.version, 2);
    assert_eq!(*h.lock().unwrap(), vec![2u8 ^ 2u8; 4 << 10]);
    // Nonexistent version: None, and regions untouched.
    assert!(client.restart_version("pin", 99).unwrap().is_none());
}

#[test]
fn dram_exhaustion_falls_back_to_next_local_tier() {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.fabric.dram_capacity = 16 << 10; // tiny staging area
    cfg.stack.erasure_group = 0;
    cfg.stack.with_partner = false;
    let rt = VelocRuntime::new(cfg).unwrap();
    let client = rt.client(0);
    client.mem_protect(0, vec![7u8; 64 << 10]); // > DRAM capacity
    client.checkpoint("big", 1).unwrap();
    client.checkpoint_wait_done("big", 1).unwrap();
    rt.drain();
    // Landed on NVMe, not DRAM.
    let tiers = rt.env().fabric.local_tiers(0);
    assert_eq!(tiers[0].used_bytes(), 0, "dram must be skipped");
    assert!(tiers[1].used_bytes() > 0, "nvme holds the copy");
    // And restores fine.
    let h = client.mem_protect(0, Vec::new());
    let info = client.restart("big").unwrap().unwrap();
    assert_eq!(info.level, LEVEL_LOCAL);
    assert_eq!(h.lock().unwrap().len(), 64 << 10);
}

#[test]
fn without_erasure_partner_pair_loss_degrades_to_pfs() {
    let mut cfg = VelocConfig::default().with_nodes(8, 1);
    cfg.stack.erasure_group = 0; // no erasure level
    let rt = VelocRuntime::new(cfg).unwrap();
    ckpt_all(&rt, "deg", 1, 8 << 10);
    rt.inject_failure(&FailureScope::MultiNode(vec![2, 3]));
    rt.revive_all();
    // Rank 2 lost local + partner; with no erasure only the PFS serves.
    let client = rt.client(2);
    client.mem_protect(0, Vec::new());
    let info = client.restart("deg").unwrap().unwrap();
    assert_eq!(info.level, LEVEL_PFS);
}

#[test]
fn wait_times_out_for_unknown_checkpoint() {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.wait_timeout = Duration::from_millis(50);
    let rt = VelocRuntime::new(cfg).unwrap();
    let client = rt.client(0);
    let st = client.checkpoint_wait("never", 1).unwrap();
    assert_eq!(st, veloc::pipeline::CkptStatus::TimedOut);
}

/// Satellite regression: a checkpoint whose engine never settles (async
/// tail held behind the paused backend) must resolve `checkpoint_wait`
/// into the *typed* timeout status within the configured timeout — the
/// old behaviour was a stringly error, the failure mode a hang.
#[test]
fn wait_on_stalled_engine_times_out_typed_not_hanging() {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.stack.erasure_group = 0;
    cfg.wait_timeout = Duration::from_millis(200);
    let rt = VelocRuntime::new(cfg).unwrap();
    let client = rt.client(0);
    client.mem_protect(0, vec![7u8; 4 << 10]);
    // Hold the async tail so the command stays unsettled for the wait.
    rt.backend().pause_background(true);
    client.checkpoint("stall", 1).unwrap();
    let t0 = std::time::Instant::now();
    let st = client.checkpoint_wait("stall", 1).unwrap();
    assert_eq!(st, veloc::pipeline::CkptStatus::TimedOut);
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "typed timeout, not a hang: {:?}",
        t0.elapsed()
    );
    // Releasing the backend settles the same command.
    rt.backend().pause_background(false);
    let st = client.checkpoint_wait("stall", 1).unwrap();
    assert!(
        matches!(st, veloc::pipeline::CkptStatus::Done(_)),
        "{st:?}"
    );
    rt.drain();
}

#[test]
fn duplicate_version_overwrites_cleanly() {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.stack.erasure_group = 0;
    let rt = VelocRuntime::new(cfg).unwrap();
    let client = rt.client(0);
    let h = client.mem_protect(0, vec![1u8; 4 << 10]);
    client.checkpoint("dup", 1).unwrap();
    client.checkpoint_wait_done("dup", 1).unwrap();
    *h.lock().unwrap() = vec![2u8; 4 << 10];
    client.checkpoint("dup", 1).unwrap(); // same version again
    client.checkpoint_wait_done("dup", 1).unwrap();
    rt.drain();
    let h2 = client.mem_protect(0, Vec::new());
    client.restart("dup").unwrap().unwrap();
    assert_eq!(*h2.lock().unwrap(), vec![2u8; 4 << 10]);
}

#[test]
fn unprotected_region_ids_ignored_on_restore() {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.stack.erasure_group = 0;
    let rt = VelocRuntime::new(cfg).unwrap();
    let client = rt.client(0);
    client.mem_protect(0, vec![1u8; 128]);
    client.mem_protect(7, vec![2u8; 128]);
    client.checkpoint("r", 1).unwrap();
    client.checkpoint_wait_done("r", 1).unwrap();
    rt.drain();
    // New client protects only region 7: restore fills it, skips 0.
    let c2 = rt.client(0);
    let h7 = c2.mem_protect(7, Vec::new());
    let info = c2.restart("r").unwrap().unwrap();
    assert_eq!(info.version, 1);
    assert_eq!(*h7.lock().unwrap(), vec![2u8; 128]);
}

/// Aggregation-enabled runtime where the PFS containers are the only
/// remote copy (no partner/erasure), so damage to them is observable.
fn agg_rt(nodes: usize) -> Arc<VelocRuntime> {
    let mut cfg = VelocConfig::default().with_nodes(nodes, 1);
    cfg.stack.erasure_group = 0;
    cfg.stack.with_partner = false;
    cfg.aggregation.enabled = true;
    VelocRuntime::new(cfg).unwrap()
}

/// A fresh aggregator over the same fabric — the cold-restart view with an
/// empty in-memory index (forces the persisted-index / rebuild paths).
fn cold_aggregator(rt: &Arc<VelocRuntime>) -> Arc<Aggregator> {
    Aggregator::new(
        rt.topology(),
        Arc::clone(&rt.env().fabric),
        rt.config().aggregation.clone(),
        None,
        None,
    )
}

#[test]
fn truncated_aggregated_container_falls_back_to_older_version() {
    let rt = agg_rt(2);
    ckpt_all(&rt, "trunc", 1, 8 << 10);
    ckpt_all(&rt, "trunc", 2, 8 << 10);
    // Truncate every container holding a v2 segment (headers survive; the
    // payload region does not).
    let pfs = rt.env().fabric.pfs();
    for key in pfs.list("agg.g") {
        let (bytes, _) = pfs.get(&key).unwrap();
        let header = container::decode_header(&bytes).unwrap();
        if header.segments.iter().any(|s| s.version == 2) {
            pfs.put(&key, &bytes[..bytes.len() / 2]).unwrap();
        }
    }
    for node in 0..2 {
        rt.env().fabric.fail_node(node);
    }
    let client = rt.client(0);
    client.mem_protect(0, Vec::new());
    let info = client.restart("trunc").unwrap().expect("fallback restore");
    assert_eq!(
        info.version, 1,
        "truncated v2 container must degrade to the older intact version"
    );
}

#[test]
fn corrupted_segment_index_rebuilds_from_container_headers() {
    let rt = agg_rt(2);
    ckpt_all(&rt, "cidx", 1, 8 << 10);
    let pfs = rt.env().fabric.pfs();
    pfs.put(INDEX_KEY, b"{ definitely not an index }").unwrap();
    // Cold aggregator: the garbage persisted index must not poison it —
    // restore falls through to the header rebuild.
    let cold = cold_aggregator(&rt);
    let data = cold
        .restore("cidx", 1, 1)
        .unwrap()
        .expect("rebuild from headers");
    let ckpt = veloc::util::bytes::Checkpoint::decode(&data).unwrap();
    assert_eq!(ckpt.region(0).unwrap().data, vec![1u8 ^ 1u8; 8 << 10]);
    // The rebuild healed the persisted index.
    let (fixed, _) = pfs.get(INDEX_KEY).unwrap();
    assert!(veloc::util::json::Json::parse(std::str::from_utf8(&fixed).unwrap()).is_ok());
}

#[test]
fn missing_index_rebuilt_from_container_headers() {
    let rt = agg_rt(2);
    ckpt_all(&rt, "midx", 1, 8 << 10);
    assert!(rt.env().fabric.pfs().delete(INDEX_KEY));
    let cold = cold_aggregator(&rt);
    let data = cold
        .restore("midx", 1, 0)
        .unwrap()
        .expect("rebuild from headers");
    let ckpt = veloc::util::bytes::Checkpoint::decode(&data).unwrap();
    assert_eq!(ckpt.region(0).unwrap().data, vec![0u8 ^ 1u8; 8 << 10]);
    assert!(rt.env().fabric.pfs().exists(INDEX_KEY), "index re-persisted");
}

#[test]
fn mem_unprotect_removes_region_from_next_checkpoint() {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.stack.erasure_group = 0;
    let rt = VelocRuntime::new(cfg).unwrap();
    let client = rt.client(0);
    client.mem_protect(0, vec![1u8; 64]);
    client.mem_protect(1, vec![2u8; 64]);
    assert_eq!(client.protected_bytes(), 128);
    client.mem_unprotect(1);
    assert_eq!(client.protected_bytes(), 64);
    client.checkpoint("u", 1).unwrap();
    client.checkpoint_wait_done("u", 1).unwrap();
    rt.drain();
    assert_eq!(
        rt.env().registry.info("u", 1, 0).unwrap().bytes,
        64,
        "dropped region must not be captured"
    );
}

#[test]
fn corrupted_compressed_pfs_copy_is_rejected() {
    // Digest-after-decompress regression: the recorded digest covers the
    // canonical captured container, so damage to the *compressed* PFS
    // object must surface as a failed decode or a failed digest — never as
    // silently-served wrong bytes.
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.stack.erasure_group = 0;
    cfg.stack.with_partner = false;
    cfg.stack.with_compression = true;
    let rt = VelocRuntime::new(cfg).unwrap();
    let client = rt.client(0);
    client.mem_protect(0, vec![42u8; 64 << 10]); // highly compressible
    client.checkpoint("comp", 1).unwrap();
    client.checkpoint_wait_done("comp", 1).unwrap();
    rt.drain();

    let key = "pfs.comp.r0.v1";
    let (mut obj, _) = rt.env().fabric.pfs().get(key).expect("PFS copy");
    assert!(
        obj.len() < 64 << 10,
        "PFS copy must be the compressed container ({} bytes)",
        obj.len()
    );
    let mid = obj.len() / 2;
    for b in &mut obj[mid..mid + 8] {
        *b ^= 0xFF;
    }
    rt.env().fabric.pfs().put(key, &obj).unwrap();

    // Kill the local tiers: the damaged PFS object is the only copy left.
    for node in 0..2 {
        rt.env().fabric.fail_node(node);
    }
    let h = client.mem_protect(0, Vec::new());
    match client.restart("comp") {
        Ok(Some(info)) => panic!(
            "corrupted compressed copy served as v{} from level {}",
            info.version, info.level
        ),
        Ok(None) | Err(_) => {}
    }
    assert!(
        h.lock().unwrap().is_empty(),
        "no bytes may be installed from a corrupted copy"
    );
}
