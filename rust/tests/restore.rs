//! Integration tests for the restore-side serving plane: the `restore.*`
//! metrics move during a restart storm, and concurrent restores of one
//! container coalesce into a single source fetch.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::app::IterativeApp;

fn runtime() -> Arc<VelocRuntime> {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.stack.erasure_group = 0;
    // Delta chains give the prefetcher something to pipeline.
    cfg.delta.enabled = true;
    cfg.delta.min_chunk = 64;
    cfg.delta.avg_chunk = 256;
    cfg.delta.max_chunk = 1024;
    cfg.delta.max_chain = 8;
    VelocRuntime::new(cfg).unwrap()
}

/// Satellite regression: a cold restore moves the miss and prefetch
/// counters, a warm restore of the same version moves the hit counter and
/// adds no misses — and both serve bit-for-bit bytes.
#[test]
fn storm_moves_cache_and_prefetch_metrics() {
    let rt = runtime();
    let client = rt.client(0);
    let mut app = IterativeApp::new(&client, "app", 2, 8 << 10, 0.0, 7);
    let mut last = 0;
    for _ in 0..4 {
        app.step();
        last = app.checkpoint(&client).unwrap();
        client.checkpoint_wait_done("app", last).unwrap();
    }
    rt.drain();
    let shadow = app.snapshot();
    let m = rt.metrics().clone();
    assert_eq!(m.counter("restore.cache.hits"), 0, "writes must not touch the cache");

    // Cold restore: misses populate the cache, the chain prefetcher runs
    // on the delta container's predicted hop list.
    let fresh = rt.client(0);
    let app2 = IterativeApp::new(&fresh, "app", 2, 8 << 10, 0.0, 7);
    let info = fresh
        .restart_version("app", last)
        .unwrap()
        .expect("cold restore");
    assert_eq!(info.version, last);
    assert!(app2.diff_snapshot(&shadow).is_empty());
    let cold_misses = m.counter("restore.cache.misses");
    assert!(cold_misses >= 1, "cold restore must miss");
    assert!(
        m.counter("restore.prefetch.issued") >= 1,
        "a mid-chain delta restore must issue chain prefetches"
    );
    assert!(m.gauge("restore.prefetch.depth") >= 1, "depth gauge never set");

    // Warm restore: served out of the cache, not the tiers.
    let fresh = rt.client(0);
    let app3 = IterativeApp::new(&fresh, "app", 2, 8 << 10, 0.0, 7);
    fresh
        .restart_version("app", last)
        .unwrap()
        .expect("warm restore");
    assert!(app3.diff_snapshot(&shadow).is_empty());
    assert!(m.counter("restore.cache.hits") >= 1, "warm restore must hit");
    assert_eq!(
        m.counter("restore.cache.misses"),
        cold_misses,
        "a warm restore must not refetch"
    );
}

/// Concurrent restores of one container issue exactly one source read:
/// the leader's fetch is held open until every storm thread has had time
/// to arrive, so late arrivals join the in-flight fetch (coalesced) or
/// hit the cache — never refetch.
#[test]
fn concurrent_fetches_coalesce_into_one_source_read() {
    const STORM: usize = 6;
    let rt = runtime();
    let eng = rt.restore_engine().expect("restore plane on").clone();
    let m = rt.metrics().clone();
    let fetches = Arc::new(AtomicU64::new(0));
    let (tx, rx) = mpsc::channel::<()>();
    let rx = Arc::new(Mutex::new(rx));

    let handles: Vec<_> = (0..STORM)
        .map(|_| {
            let eng = Arc::clone(&eng);
            let fetches = Arc::clone(&fetches);
            let rx = Arc::clone(&rx);
            std::thread::spawn(move || {
                let fetch = |_v: u64| -> anyhow::Result<Option<Vec<u8>>> {
                    fetches.fetch_add(1, Ordering::SeqCst);
                    // Hold the flight open until the main thread releases
                    // it, so the other storm threads arrive in-flight.
                    let _ = rx.lock().unwrap().recv_timeout(Duration::from_secs(10));
                    Ok(Some(vec![9u8; 4096]))
                };
                eng.fetch_container("pfs", "storm", 0, 0, 1, &fetch)
                    .unwrap()
                    .unwrap()
            })
        })
        .collect();

    // Wait for the leader to enter its fetch, give the rest time to join
    // the flight, then release.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while fetches.load(Ordering::SeqCst) == 0 {
        assert!(std::time::Instant::now() < deadline, "no leader fetch");
        std::thread::sleep(Duration::from_millis(5));
    }
    std::thread::sleep(Duration::from_millis(100));
    tx.send(()).unwrap();
    for h in handles {
        let data = h.join().unwrap();
        assert_eq!(*data, vec![9u8; 4096]);
    }

    assert_eq!(
        fetches.load(Ordering::SeqCst),
        1,
        "one source read must serve the whole storm"
    );
    assert_eq!(m.counter("restore.cache.misses"), 1);
    assert_eq!(
        m.counter("restore.cache.hits") + m.counter("restore.singleflight.coalesced"),
        (STORM - 1) as u64,
        "every non-leader is a hit or a coalesced join"
    );
}
