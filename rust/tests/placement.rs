//! Adaptive tier-placement regressions through the full runtime: flushes
//! fail over when the primary tier degrades administratively (read-only,
//! offline), the actual destination is recorded, and restores locate and
//! verify checkpoints wherever they landed.

use std::sync::Arc;
use veloc::api::{VelocConfig, VelocRuntime};
use veloc::cluster::FailureScope;
use veloc::storage::PlacementPolicy;

/// Runtime with placement over [pfs, burst-buffer] and no lateral levels
/// (partner/erasure off), so a node-failure restore must come from the
/// level-4 copy — wherever placement put it.
fn placement_runtime(policy: PlacementPolicy, aggregation: bool) -> Arc<VelocRuntime> {
    let mut cfg = VelocConfig::default().with_nodes(2, 1);
    cfg.placement.enabled = true;
    cfg.placement.policy = policy;
    cfg.fabric.with_burst_buffer = true;
    cfg.stack.with_partner = false;
    cfg.stack.erasure_group = 0;
    cfg.stack.keep_versions = 8;
    cfg.aggregation.enabled = aggregation;
    VelocRuntime::new(cfg).expect("runtime")
}

/// Satellite regression: the primary tier flips read-only between two
/// checkpoints; the second flush lands on the fallback tier, the
/// destination is recorded in the registry, and after a node failure the
/// restore locates and verifies the checkpoint from that destination.
#[test]
fn read_only_primary_fails_over_and_restore_verifies() {
    let rt = placement_runtime(PlacementPolicy::Static, false);
    let client = rt.client(0);
    let region = client.mem_protect(0, vec![1u8; 64 << 10]);

    client.checkpoint("app", 1).unwrap();
    client.checkpoint_wait_done("app", 1).unwrap();
    rt.drain();
    assert_eq!(
        rt.env().registry.info("app", 1, 0).unwrap().dest.as_deref(),
        Some("pfs"),
        "healthy static placement keeps the legacy destination"
    );

    // The PFS remounts read-only mid-run (a real Lustre failure mode).
    rt.env().fabric.pfs().set_read_only(true);
    let v2_bytes: Vec<u8> = {
        let mut g = region.lock().unwrap();
        g.iter_mut().for_each(|b| *b = 7);
        g.clone()
    };
    client.checkpoint("app", 2).unwrap();
    client.checkpoint_wait_done("app", 2).unwrap();
    rt.drain();
    assert_eq!(
        rt.env().registry.info("app", 2, 0).unwrap().dest.as_deref(),
        Some("burst-buffer"),
        "read-only primary must fail the flush over"
    );
    assert!(rt.placement().unwrap().failover_count() >= 1);
    assert!(
        !rt.env().fabric.pfs().exists("pfs.app.r0.v2"),
        "nothing may be written to a read-only tier"
    );

    // Node 0 dies: the local copy is gone, so the restore must come from
    // the recorded level-4 destination.
    rt.inject_failure(&FailureScope::Node(0));
    rt.revive_all();
    let info = client
        .restart_version("app", 2)
        .unwrap()
        .expect("v2 must be restorable from the fallback tier");
    assert_eq!(info.version, 2);
    assert_eq!(info.level, 4, "served by the level-4 copy");
    assert_eq!(
        *region.lock().unwrap(),
        v2_bytes,
        "restored bytes must match the checkpointed state bit-for-bit"
    );
}

/// A full outage of the primary during aggregated drains: containers land
/// on the burst buffer, and a rank restores out of them while the primary
/// is still down.
#[test]
fn aggregated_drains_fail_over_during_primary_outage() {
    let rt = placement_runtime(PlacementPolicy::Static, true);
    let client = rt.client(0);
    let region = client.mem_protect(0, vec![3u8; 32 << 10]);
    let expected: Vec<u8> = region.lock().unwrap().clone();

    rt.env().fabric.pfs().set_down(true);
    client.checkpoint("app", 1).unwrap();
    client.checkpoint_wait_done("app", 1).unwrap();
    rt.drain();
    assert!(
        !rt.env()
            .fabric
            .burst_buffer()
            .unwrap()
            .list("agg.g")
            .is_empty(),
        "the container must have drained to the fallback tier"
    );

    rt.inject_failure(&FailureScope::Node(0));
    rt.revive_all();
    let info = client
        .restart_version("app", 1)
        .unwrap()
        .expect("restorable from the failed-over container");
    assert_eq!(info.version, 1);
    assert_eq!(*region.lock().unwrap(), expected);
}

/// The README cookbook's example configs stay runnable: every JSON under
/// `examples/configs/` must parse and validate.
#[test]
fn example_configs_parse_and_validate() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/configs");
    let mut n = 0;
    for entry in std::fs::read_dir(&dir).expect("examples/configs exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) == Some("json") {
            VelocConfig::from_file(&path)
                .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            n += 1;
        }
    }
    assert!(n >= 4, "expected the cookbook configs, found {n}");
}

/// Adaptive policy end-to-end: fastest-eligible prefers the burst buffer
/// outright (it wins on bandwidth and latency), and checkpoints restore
/// from there without any failure at all.
#[test]
fn fastest_eligible_routes_to_burst_buffer_and_restores() {
    let rt = placement_runtime(PlacementPolicy::FastestEligible, false);
    let client = rt.client(0);
    let region = client.mem_protect(0, vec![9u8; 16 << 10]);
    let expected: Vec<u8> = region.lock().unwrap().clone();

    client.checkpoint("app", 1).unwrap();
    client.checkpoint_wait_done("app", 1).unwrap();
    rt.drain();
    assert_eq!(
        rt.env().registry.info("app", 1, 0).unwrap().dest.as_deref(),
        Some("burst-buffer"),
        "fastest-eligible must pick the faster tier"
    );

    rt.inject_failure(&FailureScope::Node(0));
    rt.revive_all();
    let info = client.restart("app").unwrap().expect("restorable");
    assert_eq!(info.version, 1);
    assert_eq!(*region.lock().unwrap(), expected);
}
