//! The crash–recover–verify scenario matrix: stack permutations (sync vs
//! async engine, partner vs XOR erasure group sizes, aggregation on/off,
//! tier policies) crossed with every injection-point family (between
//! pipeline modules, mid-transfer-chunk, mid-aggregation-drain, the
//! pre-index crash window, mid-restart). Every scenario verifies restored
//! application state bit-for-bit against shadow copies and asserts the
//! `FailureScope::min_level` contract; every failure message carries the
//! seed and the exact `veloc sim --json '...'` repro line.

use veloc::pipeline::EngineMode;
use veloc::sim::{
    base_spec, replay_file, run_scenario, run_scenario_traced, run_scenario_with_obs,
    run_scenario_with_tracer, standard_matrix, InjectionPoint, ScopeKind,
};

/// The full sweep: >= 24 distinct (stack-permutation x injection-point)
/// scenarios, all passing. A failing scenario prints its seed and the
/// one-line CLI repro.
#[test]
fn standard_matrix_covers_and_passes() {
    let specs = standard_matrix(0x5EED);
    assert!(
        specs.len() >= 24,
        "matrix shrank below the 24-scenario floor: {}",
        specs.len()
    );
    let mut stacks = std::collections::BTreeSet::new();
    let mut points = std::collections::BTreeSet::new();
    for spec in &specs {
        stacks.insert(format!(
            "{:?}/{}/{}/{}",
            spec.engine_mode, spec.with_partner, spec.erasure_group, spec.aggregation
        ));
        points.insert(spec.inject.name());
    }
    assert!(stacks.len() >= 5, "stack permutations: {stacks:?}");
    assert!(points.len() >= 10, "injection points: {points:?}");

    let mut failures = Vec::new();
    for spec in &specs {
        if let Err(e) = run_scenario(spec) {
            failures.push(format!("{e:#}"));
        }
    }
    assert!(
        failures.is_empty(),
        "{}/{} scenarios failed:\n{}",
        failures.len(),
        specs.len(),
        failures.join("\n")
    );
}

/// Determinism: the same spec yields byte-identical event traces, for one
/// representative of each injection mechanism.
#[test]
fn traces_replay_exactly_from_their_seed() {
    let specs = standard_matrix(77);
    let pick = |f: &dyn Fn(&InjectionPoint) -> bool| {
        specs
            .iter()
            .find(|s| f(&s.inject))
            .expect("matrix covers every mechanism")
    };
    let representatives = [
        pick(&|i| matches!(i, InjectionPoint::AfterCheckpoint)),
        pick(&|i| matches!(i, InjectionPoint::BeforeModule(_))),
        pick(&|i| matches!(i, InjectionPoint::MidFlushChunk(_))),
        pick(&|i| matches!(i, InjectionPoint::MidDrainPreIndex)),
        pick(&|i| matches!(i, InjectionPoint::MidRestart(_))),
    ];
    for spec in representatives {
        let (r1, t1) = run_scenario_traced(spec);
        r1.unwrap_or_else(|e| panic!("{e:#}"));
        let (r2, t2) = run_scenario_traced(spec);
        r2.unwrap_or_else(|e| panic!("{e:#}"));
        if let Some(diff) = t1.diff(&t2) {
            panic!(
                "nondeterministic trace for {} (seed {}): {diff}",
                spec.inject.name(),
                spec.seed
            );
        }
    }
}

/// A saved trace replays exactly through the file-based replay path (the
/// `veloc sim --replay` workflow).
#[test]
fn saved_trace_replays_via_file() {
    let spec = base_spec(0xBEEF1);
    let (result, trace) = run_scenario_traced(&spec);
    result.unwrap_or_else(|e| panic!("{e:#}"));
    let dir = std::env::temp_dir().join("veloc-scenarios-test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("replay.json");
    trace.save(&spec, &path).unwrap();
    let report = replay_file(&path).unwrap_or_else(|e| panic!("{e:#}"));
    assert_eq!(report.spec, spec);
    let _ = std::fs::remove_file(&path);
}

/// Satellite: aggregation restart race — a node dies between container
/// drain and index persist; recovery must rebuild the index from the
/// self-describing container headers and still serve the final wave.
#[test]
fn aggregation_drain_index_race_rebuilds_from_headers() {
    for engine in [EngineMode::Async, EngineMode::Sync] {
        let spec = standard_matrix(0xA66)
            .into_iter()
            .find(|s| {
                s.inject == InjectionPoint::MidDrainPreIndex && s.engine_mode == engine
            })
            .expect("matrix carries pre-index scenarios for both engines");
        let report = run_scenario(&spec).unwrap_or_else(|e| panic!("{e:#}"));
        assert!(
            report.index_rebuilds >= 1,
            "{engine:?}: recovery must rebuild the segment index from container headers"
        );
        assert_eq!(
            report.frontier,
            Some(spec.waves * spec.steps_per_wave),
            "{engine:?}: the durable-but-unindexed container must serve the final wave"
        );
        assert_eq!(
            report.verified_ranks,
            spec.nodes * spec.ranks_per_node,
            "{engine:?}: every rank must verify bit-for-bit"
        );
    }
}

/// Satellite: adaptive-placement scenarios — a mid-run shared-tier outage
/// fails the final wave's flushes over to the burst buffer (direct and
/// aggregated paths), a degraded tier is routed around by the adaptive
/// policy, and every restore still verifies bit-for-bit against the
/// shadow copies (the runner additionally asserts the failover /
/// re-routing metrics inside each scenario).
#[test]
fn placement_tier_outage_and_degradation_scenarios_pass() {
    let specs: Vec<_> = standard_matrix(0x71E6)
        .into_iter()
        .filter(|s| {
            matches!(
                s.inject,
                InjectionPoint::TierOutage(_) | InjectionPoint::TierDegraded(_, _)
            )
        })
        .collect();
    assert!(
        specs.len() >= 3,
        "matrix must carry tier-outage and tier-degraded scenarios: {}",
        specs.len()
    );
    assert!(
        specs.iter().any(|s| s.aggregation),
        "an aggregated tier-outage scenario must be covered"
    );
    for spec in &specs {
        let report = run_scenario(spec).unwrap_or_else(|e| panic!("{e:#}"));
        assert_eq!(
            report.frontier,
            Some(spec.waves * spec.steps_per_wave),
            "{}: a tier fault with a healthy fallback must not cost the \
             latest version",
            spec.inject.name()
        );
        assert_eq!(
            report.verified_ranks,
            spec.nodes * spec.ranks_per_node,
            "{}: every rank must verify bit-for-bit",
            spec.inject.name()
        );
    }
}

/// Tentpole acceptance: a backend-crash scenario run with a flight
/// directory leaves a crash-durable dump that `postmortem --verify` can
/// fully reconstruct — sim + daemon streams verify clean across both
/// daemon incarnations, the timeline shows the final wave acked but
/// unsettled at the instant of the crash (and settled after replay), and
/// the persisted signals survive with live failure-interarrival and
/// tier-health series.
#[test]
fn backend_crash_flight_dump_reconstructs_the_crash() {
    use veloc::obs::flight;
    use veloc::obs::{FlightKind, SignalsView};

    let spec = {
        let mut s = standard_matrix(0xF117)
            .into_iter()
            .find(|s| matches!(s.inject, InjectionPoint::BackendCrash))
            .expect("matrix carries a backend-crash scenario");
        // Adaptive placement so the tier-health signal has live series.
        s.placement = Some("fastest-eligible".to_string());
        s
    };
    let dir = std::env::temp_dir().join(format!("veloc-flight-crash-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let (result, _trace) = run_scenario_with_obs(&spec, None, Some(&dir));
    result.unwrap_or_else(|e| panic!("{e:#}"));

    let scans = flight::read_dir(&dir).unwrap();
    let report = flight::verify(&scans).unwrap_or_else(|e| panic!("verify FAILED: {e}"));
    assert!(
        report.processes.iter().any(|p| p == "daemon"),
        "daemon stream missing: {:?}",
        report.processes
    );
    assert!(
        report.processes.iter().any(|p| p == "sim"),
        "sim stream missing: {:?}",
        report.processes
    );
    assert!(report.snapshots > 0, "no persisted signals snapshots");

    let merged = flight::merge(&scans);
    let crash_at = merged
        .iter()
        .position(|e| {
            e.kind == FlightKind::Event && e.body.str_or("name", "") == "daemon.crash"
        })
        .expect("daemon.crash event on the timeline");
    // At the instant of the crash the final wave is acked, journaled and
    // unsettled — one stranded submission per rank, at the last version.
    let world = spec.nodes * spec.ranks_per_node;
    let last_version = (spec.waves * spec.steps_per_wave).to_string();
    let stranded = flight::unsettled(&merged[..=crash_at]);
    assert_eq!(
        stranded.len(),
        world,
        "one acked-but-unsettled submission per rank: {stranded:?}"
    );
    for s in &stranded {
        assert_eq!(s.str_or("version", "?"), last_version, "{s:?}");
    }
    // After the second incarnation's journal replay, the books balance.
    assert!(
        flight::unsettled(&merged).is_empty(),
        "replay must settle every stranded ack"
    );

    let view = SignalsView::from_entries(&merged);
    let failures = view
        .failure_interarrival()
        .expect("failure inter-arrival series persisted");
    assert!(!failures.points.is_empty());
    assert!(
        !view.tier_health().is_empty(),
        "tier health series persisted; got {:?}",
        view.names()
    );

    let _ = std::fs::remove_dir_all(&dir);
}

/// Tentpole acceptance: critical-path attribution over a traced
/// tier-degraded run names the injected slow tier. The degradation lands
/// before the penultimate wave; that wave's transfer rides the degraded
/// tier and must dominate its critical path with the tier label carried
/// through for blame.
#[test]
fn tier_degraded_analyze_names_the_slow_tier() {
    use veloc::obs::{critpath, TraceRecorder};

    let spec = standard_matrix(0x71E77)
        .into_iter()
        .find(|s| matches!(s.inject, InjectionPoint::TierDegraded(_, _)))
        .expect("matrix carries a tier-degraded scenario");
    let InjectionPoint::TierDegraded(ref slow_tier, _) = spec.inject else {
        unreachable!()
    };
    let tracer = TraceRecorder::new(true);
    let (result, _trace) = run_scenario_with_tracer(&spec, Some(std::sync::Arc::clone(&tracer)));
    result.unwrap_or_else(|e| panic!("{e:#}"));

    let waves = critpath::analyze(&tracer.snapshot());
    assert!(
        waves.len() >= spec.waves as usize,
        "every completed wave analyzes: got {} of {}",
        waves.len(),
        spec.waves
    );
    let blamed = waves.iter().find(|w| {
        w.blame
            .iter()
            .any(|b| b.tier.as_deref() == Some(slow_tier.as_str()))
    });
    let blamed = blamed.unwrap_or_else(|| {
        panic!(
            "no wave blames the injected slow tier {slow_tier}: {:?}",
            waves
                .iter()
                .map(|w| (w.version, w.blame.first().map(|b| (b.stage.clone(), b.tier.clone()))))
                .collect::<Vec<_>>()
        )
    });
    // The degraded tier is blamed through the transfer stage, and the
    // human report carries the attribution.
    assert!(
        blamed
            .blame
            .iter()
            .any(|b| b.stage == "transfer" && b.tier.as_deref() == Some(slow_tier.as_str())),
        "blame: {:?}",
        blamed.blame
    );
    assert!(critpath::render(&waves).contains(&format!("tier={slow_tier}")));
}

/// A failing exploration shrinks to `seed + spec`: the error message
/// carries both the seed and the exact CLI repro line.
#[test]
fn failing_run_reports_seed_and_repro() {
    let mut spec = base_spec(1234);
    spec.erasure_group = 3; // invalid: 4 nodes % 3 != 0
    let err = run_scenario(&spec).unwrap_err().to_string();
    assert!(err.contains("seed 1234"), "{err}");
    assert!(err.contains("veloc sim --json '"), "{err}");
}

/// The negative contract case: a system outage before any level-4 flush
/// completed leaves nothing recoverable — and the engine must predict
/// exactly that (frontier None on both sides).
#[test]
fn unflushed_system_outage_is_unrecoverable_and_predicted() {
    let mut spec = base_spec(0xDEAD5);
    spec.waves = 1;
    spec.scope = veloc::sim::ScopeSpec {
        kind: ScopeKind::System,
        target: None,
    };
    spec.inject = InjectionPoint::BeforeModule("transfer".to_string());
    let report = run_scenario(&spec).unwrap_or_else(|e| panic!("{e:#}"));
    assert_eq!(report.expected_frontier, None);
    assert_eq!(report.frontier, None);
    assert_eq!(report.verified_ranks, 0);
}

/// Satellite: a torn mid-chain delta flush (manifest durable, chunks
/// stripped) forces recovery past the break — at worst to the last forced
/// full — and the fallback still verifies bit-for-bit.
#[test]
fn delta_chain_break_falls_back_past_the_break() {
    let spec = standard_matrix(0xDE17A)
        .into_iter()
        .find(|s| matches!(s.inject, InjectionPoint::DeltaChainBreak(_)))
        .expect("matrix carries a delta chain-break scenario");
    let report = run_scenario(&spec).unwrap_or_else(|e| panic!("{e:#}"));
    let spw = spec.steps_per_wave;
    // waves = 6, chain of 3: fulls at checkpoints 1 and 4; the break at
    // the 5th strands checkpoints 5 and 6, so the guaranteed frontier is
    // the last full.
    assert_eq!(
        report.expected_frontier,
        Some(4 * spw),
        "guaranteed fallback is the last forced full"
    );
    let frontier = report.frontier.expect("a restorable version must remain");
    assert!(frontier >= 4 * spw, "served {frontier}");
    assert_eq!(
        report.verified_ranks,
        spec.nodes * spec.ranks_per_node,
        "every rank must verify bit-for-bit at the fallback"
    );
}

/// Satellite: a GC writer dying after persisting its decref intent is
/// recovered by the refcount-ledger replay; the scenario runner asserts
/// the replay count, re-verifies the previous retained version and audits
/// every live manifest against the chunk stores.
#[test]
fn delta_gc_crash_recovers_via_ledger_replay() {
    let spec = standard_matrix(0x6C6C)
        .into_iter()
        .find(|s| matches!(s.inject, InjectionPoint::DeltaGcCrash))
        .expect("matrix carries a delta gc-crash scenario");
    let report = run_scenario(&spec).unwrap_or_else(|e| panic!("{e:#}"));
    assert_eq!(
        report.frontier,
        Some(spec.waves * spec.steps_per_wave),
        "a rank-scoped GC crash must not cost the latest version"
    );
    assert!(
        report.verified_ranks > spec.nodes * spec.ranks_per_node,
        "the runner re-verifies the previous retained version too"
    );
}
