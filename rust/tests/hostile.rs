//! Hostile-input corruption suite — the tier-1-runnable twin of the fuzz
//! harness under `rust/fuzz/`.
//!
//! One invariant, four on-disk/wire formats: *parse returns a typed error
//! or a valid value; it never panics and never allocates off an untrusted
//! length field.* Each format gets (a) a seeded round-trip property test
//! (encode → decode identity), (b) a 1-bit-mutation property (typed error
//! or a value that re-encodes canonically), and (c) ≥200 seeded mutations
//! from the full [`veloc::sim::corrupt`] catalog — bit flips, truncation,
//! length-field inflation, record reordering, zero runs — driven through
//! the *real* parser under `catch_unwind`, so any panic names the exact
//! `(format, seed)` to replay.
//!
//! The tail tests exercise the recovery contract end to end: a corrupted
//! container degrades to partial salvage, a corrupted segment index to a
//! header rebuild, and a corrupted journal to a clean (possibly shorter)
//! replay — never a panic, never silent wrong bytes.

use std::panic::{catch_unwind, AssertUnwindSafe};
use veloc::aggregation::container::{self, SegmentMeta};
use veloc::aggregation::{SegmentIndex, SegmentLoc};
use veloc::backend::scan_records;
use veloc::backend::wire::{self, WireError};
use veloc::delta::chunker::Fingerprint;
use veloc::delta::manifest::{self, ChunkRef, DeltaManifest, RegionChunks};
use veloc::obs::flight;
use veloc::obs::SpanRec;
use veloc::sim::{mutate, refresh_crc32_trailer};
use veloc::util::json::Json;
use veloc::util::rng::Rng;

/// Seeds per (format, mutation) sweep — the acceptance floor is 200.
const SWEEP: u64 = 256;

/// Run `f`, converting a panic into a test failure that names the seed.
fn no_panic<T>(what: &str, seed: u64, f: impl FnOnce() -> T) -> T {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(v) => v,
        Err(_) => panic!("{what}: parser panicked on seed {seed}"),
    }
}

// ---------------------------------------------------------------- samples

fn sample_wire_frame() -> Vec<u8> {
    let header = Json::obj()
        .set("op", "submit")
        .set("job", "train-a")
        .set("name", "model")
        .set("version", 12u64);
    let body: Vec<u8> = (0..=255u8).cycle().take(900).collect();
    let mut buf = Vec::new();
    wire::write_frame(&mut buf, &header, &body).unwrap();
    buf
}

fn vagg_seg(name: &str, version: u64, rank: usize, data: &[u8]) -> SegmentMeta {
    SegmentMeta {
        name: name.to_string(),
        version,
        rank,
        len: data.len(),
        encoding: "raw".to_string(),
        crc: crc32fast::hash(data),
    }
}

fn sample_vagg() -> (Vec<u8>, Vec<Vec<u8>>) {
    let payloads = vec![vec![0x11u8; 120], vec![0x22u8; 300], vec![0x33u8; 33]];
    let metas: Vec<(SegmentMeta, &[u8])> = payloads
        .iter()
        .enumerate()
        .map(|(r, p)| (vagg_seg("app", 5, r, p), p.as_slice()))
        .collect();
    (container::encode("g0.c7", 0, &metas), payloads)
}

fn sample_vdlt() -> Vec<u8> {
    let a = vec![7u8; 256];
    let b: Vec<u8> = (0..200u8).collect();
    let (fa, fb) = (Fingerprint::of(&a), Fingerprint::of(&b));
    let m = DeltaManifest {
        name: "app".to_string(),
        rank: 1,
        version: 9,
        iteration: 9,
        base: Some(8),
        chain_len: 1,
        regions: vec![RegionChunks {
            id: 0,
            chunks: vec![ChunkRef { fp: fa, len: 256 }, ChunkRef { fp: fb, len: 200 }],
        }],
    };
    manifest::encode(&m, &[(fa, &a), (fb, &b)])
}

/// Hand-rolled WAL record framing (`[u32 len][json][u32 crc32]`) — the
/// journal's encoder is private on purpose; the byte layout is the public
/// contract this suite pins down.
fn wal_record(j: &Json) -> Vec<u8> {
    let body = j.to_string().into_bytes();
    let mut out = Vec::with_capacity(body.len() + 8);
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out.extend_from_slice(&crc32fast::hash(&body).to_le_bytes());
    out
}

fn sample_wal() -> (Vec<u8>, Vec<Json>) {
    let records = vec![
        Json::obj()
            .set("t", "begin")
            .set("id", 1u64)
            .set("job", "train-a")
            .set("rank", 0u64)
            .set("name", "7.train-a@model")
            .set("version", 3u64)
            .set("payload", "1.vckp"),
        Json::obj().set("t", "end").set("id", 1u64).set("ok", true),
        Json::obj()
            .set("t", "begin")
            .set("id", 2u64)
            .set("job", "train-a")
            .set("rank", 1u64)
            .set("name", "7.train-a@model")
            .set("version", 4u64)
            .set("payload", "2.vckp"),
    ];
    let mut buf = Vec::new();
    for r in &records {
        buf.extend_from_slice(&wal_record(r));
    }
    (buf, records)
}

fn sample_index() -> SegmentIndex {
    let mut idx = SegmentIndex::new();
    for rank in 0..4usize {
        idx.insert(
            "app",
            2,
            rank,
            SegmentLoc {
                container: format!("g{}.c1", rank / 2),
                offset: 64 + rank * 100,
                len: 100,
                encoding: "raw".to_string(),
                crc: 0xBEEF + rank as u32,
                tier: "pfs".to_string(),
            },
        );
    }
    idx
}

// ------------------------------------------------- round-trip properties

#[test]
fn wire_frames_roundtrip_under_seeded_inputs() {
    let mut rng = Rng::new(0x51ED);
    for case in 0..50u64 {
        let mut body = vec![0u8; rng.range_usize(0, 4096)];
        rng.fill_bytes(&mut body);
        let header = Json::obj()
            .set("op", "submit")
            .set("case", case)
            .set("len", body.len() as u64)
            .set("tag", format!("case-{case}").as_str());
        let mut buf = Vec::new();
        wire::write_frame(&mut buf, &header, &body).unwrap();
        let (h, b) = wire::read_frame(&mut std::io::Cursor::new(buf)).unwrap();
        assert_eq!(h, header, "case {case}");
        assert_eq!(b, body, "case {case}");
    }
}

#[test]
fn vagg_containers_roundtrip_under_seeded_inputs() {
    let mut rng = Rng::new(0xA6);
    for case in 0..50u64 {
        let payloads: Vec<Vec<u8>> = (0..rng.range_usize(1, 5))
            .map(|_| {
                let mut p = vec![0u8; rng.range_usize(0, 600)];
                rng.fill_bytes(&mut p);
                p
            })
            .collect();
        let metas: Vec<(SegmentMeta, &[u8])> = payloads
            .iter()
            .enumerate()
            .map(|(r, p)| (vagg_seg("app", case, r, p), p.as_slice()))
            .collect();
        let buf = container::encode("g1.c2", 3, &metas);
        let h = container::decode_header(&buf).unwrap();
        assert_eq!(h.id, "g1.c2");
        assert_eq!(h.group, 3);
        assert_eq!(
            h.segments,
            metas.iter().map(|(m, _)| m.clone()).collect::<Vec<_>>()
        );
        for (i, p) in payloads.iter().enumerate() {
            assert_eq!(&container::extract(&buf, &h, i).unwrap(), p, "case {case}");
        }
    }
}

#[test]
fn vdlt_manifests_roundtrip_under_seeded_inputs() {
    let mut rng = Rng::new(0xD17A);
    for case in 0..50u64 {
        let novel: Vec<Vec<u8>> = (0..rng.range_usize(0, 4))
            .map(|i| {
                let mut p = vec![0u8; rng.range_usize(1, 400)];
                rng.fill_bytes(&mut p);
                p.push(i as u8); // distinct payloads => distinct fingerprints
                p
            })
            .collect();
        let fps: Vec<Fingerprint> = novel.iter().map(|p| Fingerprint::of(p)).collect();
        let m = DeltaManifest {
            name: "app".to_string(),
            rank: rng.below(8) as usize,
            version: case + 1,
            iteration: case + 1,
            base: (case % 2 == 0).then_some(case),
            chain_len: case % 3,
            regions: vec![RegionChunks {
                id: 0,
                chunks: fps
                    .iter()
                    .zip(&novel)
                    .map(|(fp, p)| ChunkRef { fp: *fp, len: p.len() })
                    .collect(),
            }],
        };
        let pairs: Vec<(Fingerprint, &[u8])> =
            fps.iter().zip(&novel).map(|(f, p)| (*f, p.as_slice())).collect();
        let buf = manifest::encode(&m, &pairs);
        let (back, chunks) = manifest::decode(&buf).unwrap();
        assert_eq!(back, m, "case {case}");
        assert_eq!(chunks.len(), fps.len());
        for (fp, p) in fps.iter().zip(&novel) {
            assert_eq!(&chunks[fp], p);
        }
    }
}

#[test]
fn journal_records_roundtrip_under_seeded_inputs() {
    let mut rng = Rng::new(0x3A1);
    for case in 0..50u64 {
        let records: Vec<Json> = (0..rng.range_usize(1, 8))
            .map(|i| {
                Json::obj()
                    .set("t", if i % 2 == 0 { "begin" } else { "end" })
                    .set("id", rng.next_u64() >> 12)
                    .set("version", rng.below(1 << 20))
            })
            .collect();
        let mut buf = Vec::new();
        for r in &records {
            buf.extend_from_slice(&wal_record(r));
        }
        let back = scan_records(&buf);
        assert_eq!(back, records, "case {case}");
    }
}

#[test]
fn segment_index_roundtrips_through_its_json() {
    let idx = sample_index();
    let doc = idx.to_json();
    let mut back = SegmentIndex::new();
    back.load_json(&Json::parse(&doc.to_string()).unwrap()).unwrap();
    assert_eq!(back.to_json(), doc);
    assert_eq!(back.len(), idx.len());
    assert_eq!(back.get("app", 2, 3), idx.get("app", 2, 3));
}

// ------------------------------------------------- 1-bit mutation contract

/// Flip exactly one seeded bit in a copy of `data`.
fn flip_one_bit(data: &[u8], seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    let mut out = data.to_vec();
    let at = rng.below(out.len() as u64) as usize;
    out[at] ^= 1 << rng.below(8);
    out
}

#[test]
fn wire_one_bit_flip_is_typed_error_or_canonical_value() {
    let frame = sample_wire_frame();
    for seed in 0..SWEEP {
        let bent = flip_one_bit(&frame, seed);
        let decoded = no_panic("wire 1-bit", seed, || {
            wire::read_frame(&mut std::io::Cursor::new(&bent))
        });
        if let Ok((h, b)) = decoded {
            // A surviving value must re-encode canonically: one more
            // write/read cycle reproduces it exactly.
            let mut again = Vec::new();
            wire::write_frame(&mut again, &h, &b).unwrap();
            let (h2, b2) = wire::read_frame(&mut std::io::Cursor::new(again)).unwrap();
            assert_eq!((h2, b2), (h, b), "seed {seed} not canonical");
        }
    }
}

#[test]
fn vagg_one_bit_flip_is_typed_error_or_canonical_value() {
    let (buf, _) = sample_vagg();
    for seed in 0..SWEEP {
        let bent = flip_one_bit(&buf, seed);
        no_panic("VAGG 1-bit", seed, || {
            let Ok(h) = container::decode_header(&bent) else {
                return; // typed rejection — the degrade path
            };
            // Header survived: every segment either extracts (its CRC
            // still matches) or degrades typed; survivors re-encode
            // byte-canonically through encode_prefix.
            let mut survivors = Vec::new();
            for i in 0..h.segments.len() {
                if let Ok(p) = container::extract(&bent, &h, i) {
                    survivors.push((h.segments[i].clone(), p));
                }
            }
            let pairs: Vec<(SegmentMeta, &[u8])> = survivors
                .iter()
                .map(|(m, p)| (m.clone(), p.as_slice()))
                .collect();
            let again = container::encode(&h.id, h.group, &pairs);
            let h2 = container::decode_header(&again).unwrap();
            assert_eq!(h2.segments.len(), survivors.len(), "seed {seed}");
        });
    }
}

#[test]
fn vdlt_one_bit_flip_is_always_detected() {
    // The whole-container CRC32 detects every single-bit error by
    // construction: a 1-bit flip anywhere must yield a typed error.
    let buf = sample_vdlt();
    for seed in 0..SWEEP {
        let bent = flip_one_bit(&buf, seed);
        let r = no_panic("VDLT 1-bit", seed, || manifest::decode(&bent));
        assert!(r.is_err(), "seed {seed}: 1-bit flip slipped past the CRC");
    }
}

#[test]
fn journal_one_bit_flip_keeps_a_clean_prefix() {
    let (buf, records) = sample_wal();
    for seed in 0..SWEEP {
        let bent = flip_one_bit(&buf, seed);
        let scanned = no_panic("WAL 1-bit", seed, || scan_records(&bent));
        // The scan may stop early (at the bent record) but everything it
        // does return must be an intact prefix of the original log.
        assert!(scanned.len() <= records.len(), "seed {seed}");
        for (i, j) in scanned.iter().enumerate() {
            if *j != records[i] {
                // The flip landed inside record i's JSON body *and* kept
                // its CRC valid — impossible for a 1-bit error.
                panic!("seed {seed}: record {i} silently altered");
            }
        }
    }
}

#[test]
fn segment_index_one_bit_flip_is_typed_error_or_canonical_value() {
    let doc = sample_index().to_json().to_string().into_bytes();
    for seed in 0..SWEEP {
        let bent = flip_one_bit(&doc, seed);
        no_panic("index 1-bit", seed, || {
            let Ok(text) = std::str::from_utf8(&bent) else { return };
            let Ok(j) = Json::parse(text) else { return };
            let mut idx = SegmentIndex::new();
            if idx.load_json(&j).is_err() {
                return; // typed rejection — caller rebuilds from headers
            }
            // Survived: must re-encode canonically.
            let again = idx.to_json();
            let mut idx2 = SegmentIndex::new();
            idx2.load_json(&again).unwrap();
            assert_eq!(idx2.to_json(), again, "seed {seed} not canonical");
        });
    }
}

// --------------------------------------- full mutation-catalog sweeps

#[test]
fn wire_frames_survive_the_mutation_catalog() {
    let frame = sample_wire_frame();
    for seed in 0..SWEEP {
        let (m, bent) = mutate(&frame, seed);
        no_panic(m.name(), seed, || {
            match wire::read_frame(&mut std::io::Cursor::new(&bent)) {
                Ok(_) => {}
                Err(
                    WireError::Closed(_)
                    | WireError::HeaderTooLarge { .. }
                    | WireError::BodyTooLarge { .. }
                    | WireError::HeaderNotUtf8
                    | WireError::HeaderJson(_)
                    | WireError::Io(_),
                ) => {} // every rejection is a named variant
            }
        });
    }
}

#[test]
fn vagg_containers_survive_the_mutation_catalog() {
    let (buf, _) = sample_vagg();
    for seed in 0..SWEEP {
        let (m, bent) = mutate(&buf, seed);
        no_panic(m.name(), seed, || {
            if let Ok(h) = container::decode_header(&bent) {
                for i in 0..h.segments.len() {
                    let _ = container::extract(&bent, &h, i);
                }
                for i in 0..h.segments.len() {
                    let _ = h.segment_offset(i);
                }
            }
        });
    }
}

#[test]
fn vdlt_manifests_survive_the_mutation_catalog() {
    let buf = sample_vdlt();
    for seed in 0..SWEEP {
        // Raw mutation: usually dies at the CRC gate — still must not
        // panic on the framing checks before it.
        let (m, bent) = mutate(&buf, seed);
        no_panic(m.name(), seed, || {
            let _ = manifest::decode(&bent);
        });
        // CRC-resealed mutation: pushes the hostile bytes past the gate
        // into header/length parsing, the paths the fuzz targets live in.
        let mut resealed = bent;
        refresh_crc32_trailer(&mut resealed);
        no_panic(m.name(), seed, || {
            if let Ok((mf, _)) = manifest::decode(&resealed) {
                // A surviving manifest must re-encode canonically.
                let back = DeltaManifest::from_json(&mf.to_json()).unwrap();
                assert_eq!(back, mf, "seed {seed}");
            }
        });
    }
}

#[test]
fn journal_replay_survives_the_mutation_catalog() {
    let (buf, _) = sample_wal();
    for seed in 0..SWEEP {
        let (m, bent) = mutate(&buf, seed);
        no_panic(m.name(), seed, || {
            let _ = scan_records(&bent);
        });
    }
}

#[test]
fn segment_index_survives_the_mutation_catalog() {
    let doc = sample_index().to_json().to_string().into_bytes();
    for seed in 0..SWEEP {
        let (m, bent) = mutate(&doc, seed);
        no_panic(m.name(), seed, || {
            let Ok(text) = std::str::from_utf8(&bent) else { return };
            let Ok(j) = Json::parse(text) else { return };
            let mut idx = SegmentIndex::new();
            let _ = idx.load_json(&j);
        });
    }
}

// -------------------------------------------- end-to-end recovery contract

#[test]
fn corrupted_container_degrades_to_partial_salvage() {
    // One corrupt segment must cost exactly that segment: the others
    // extract bit-for-bit (the restore path then resolves the lost rank
    // from a deeper resilience level).
    let (mut buf, payloads) = sample_vagg();
    let h = container::decode_header(&buf).unwrap();
    let off = h.segment_offset(1);
    buf[off + 5] ^= 0x10;
    assert!(matches!(
        container::extract(&buf, &h, 1),
        Err(veloc::aggregation::ContainerError::SegmentCrc(_))
    ));
    assert_eq!(container::extract(&buf, &h, 0).unwrap(), payloads[0]);
    assert_eq!(container::extract(&buf, &h, 2).unwrap(), payloads[2]);
}

#[test]
fn corrupted_index_degrades_to_header_rebuild() {
    // The persisted segment index is a cache: when hostile bytes make it
    // unloadable, the self-describing container headers rebuild an
    // equivalent index (the Aggregator::rebuild_index recovery path).
    let (buf, payloads) = sample_vagg();
    let h = container::decode_header(&buf).unwrap();

    let mut idx = SegmentIndex::new();
    assert!(idx.load_json(&Json::obj().set("segments", "garbage")).is_err());

    let mut rebuilt = SegmentIndex::new();
    for (i, s) in h.segments.iter().enumerate() {
        rebuilt.insert(
            &s.name,
            s.version,
            s.rank,
            SegmentLoc {
                container: h.id.clone(),
                offset: h.segment_offset(i),
                len: s.len,
                encoding: s.encoding.clone(),
                crc: s.crc,
                tier: String::new(),
            },
        );
    }
    for (rank, p) in payloads.iter().enumerate() {
        let loc = rebuilt.get("app", 5, rank).unwrap();
        assert_eq!(&buf[loc.offset..loc.offset + loc.len], p.as_slice());
        assert_eq!(crc32fast::hash(p), loc.crc);
    }
}

#[test]
fn corrupted_wal_on_disk_replays_clean_for_every_seed() {
    // End to end through Journal::open: however the WAL image is bent,
    // open() must come back Ok — replaying the intact prefix, treating
    // payload-less begins as settled — and never panic or misparse.
    use veloc::backend::Journal;
    let base = std::env::temp_dir().join(format!("veloc-hostile-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let dir = base.join("seed-journal");
    let wal = {
        let (j, _) = Journal::open(&dir, false).unwrap();
        j.begin("train-a", 0, "7.train-a@model", 1, b"payload-one").unwrap();
        j.begin("train-a", 1, "7.train-a@model", 1, b"payload-two").unwrap();
        std::fs::read(dir.join("wal.log")).unwrap()
    };
    for seed in 0..64u64 {
        let (m, bent) = mutate(&wal, seed);
        let d = base.join(format!("replay-{seed}"));
        std::fs::create_dir_all(&d).unwrap();
        std::fs::write(d.join("wal.log"), &bent).unwrap();
        let opened = no_panic(m.name(), seed, || Journal::open(&d, false));
        let (_, pending) = opened.unwrap_or_else(|e| {
            panic!("{} seed {seed}: replay must not error: {e:#}", m.name())
        });
        assert!(pending.len() <= 2, "seed {seed}: invented pending entries");
    }
    let _ = std::fs::remove_dir_all(&base);
}

// -------------------------------------------------- flight-recorder streams

/// A realistic `.vfr` stream image: meta, events (an ack/settle pair plus
/// a stranded ack), a closed span, and a signals snapshot — written by
/// the real recorder so the sample tracks the format.
fn sample_flight_stream() -> Vec<u8> {
    let dir = std::env::temp_dir().join(format!(
        "veloc-hostile-flight-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let f = flight::FlightRecorder::open(&dir, "daemon", 1 << 20).unwrap();
    f.event("backend.ack", &[("id", "1"), ("job", "train-a"), ("version", "3")]);
    f.event("backend.settle", &[("id", "1"), ("ok", "true")]);
    f.event("backend.ack", &[("id", "2"), ("job", "train-a"), ("version", "4")]);
    f.span(
        &SpanRec {
            id: 1,
            parent: 0,
            name: "ckpt".to_string(),
            start_us: 10,
            end_us: Some(90),
            labels: vec![("rank".to_string(), "0".to_string())],
            tid: 0,
            instant: false,
        },
        flight::unix_us(),
    );
    let bus = veloc::obs::SignalsBus::new(8);
    bus.sample("tier.health.pfs", 1.0);
    f.signals(&bus.snapshot());
    f.flush();
    let bytes = std::fs::read(f.path()).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
    bytes
}

#[test]
fn flight_one_bit_flip_keeps_a_clean_prefix() {
    let stream = sample_flight_stream();
    let clean = flight::scan_bytes(&stream);
    assert!(clean.truncated.is_none(), "{:?}", clean.truncated);
    assert!(clean.entries.len() >= 6, "meta + 3 events + span + snapshot");
    for seed in 0..SWEEP {
        let bent = flip_one_bit(&stream, seed);
        let scan = no_panic("flight 1-bit", seed, || flight::scan_bytes(&bent));
        // Frames are CRC-trailed: a single-bit error can never decode, so
        // whatever the scan returns is an intact prefix of the original.
        assert!(scan.entries.len() <= clean.entries.len(), "seed {seed}");
        for (i, e) in scan.entries.iter().enumerate() {
            assert_eq!(
                e.body.to_string(),
                clean.entries[i].body.to_string(),
                "seed {seed}: record {i} silently altered"
            );
        }
    }
}

#[test]
fn flight_streams_survive_the_mutation_catalog() {
    let stream = sample_flight_stream();
    for seed in 0..SWEEP {
        let (m, bent) = mutate(&stream, seed);
        no_panic(m.name(), seed, || {
            let scan = flight::scan_bytes(&bent);
            // The whole postmortem read path must also hold: span
            // reconstruction, ack pairing and verify all run over
            // whatever decoded.
            for e in &scan.entries {
                let _ = flight::entry_to_span(e);
            }
            let _ = flight::unsettled(&scan.entries);
            let scans = vec![(std::path::PathBuf::from("bent.vfr"), scan)];
            let _ = flight::verify(&scans);
        });
    }
}

#[test]
fn flight_inflated_length_fields_never_size_an_allocation() {
    // A hostile length field must stop the scan with a typed reason, not
    // reach an allocator. Overwrite the first frame's length with
    // escalating lies, including the classic 0xFFFFFFFF.
    let stream = sample_flight_stream();
    let header = 8; // magic + version
    for lie in [0u32, 1, 8, (1 << 20) + 1, u32::MAX / 2, u32::MAX] {
        let mut bent = stream.clone();
        bent[header..header + 4].copy_from_slice(&lie.to_le_bytes());
        let scan = no_panic("flight length-lie", lie as u64, || flight::scan_bytes(&bent));
        assert!(scan.entries.is_empty(), "len {lie}: decoded a lying frame");
        assert!(scan.truncated.is_some(), "len {lie}: no typed truncation reason");
    }
    // A length that stays in bounds but points past the real frame end:
    // the CRC trailer is recomputed over the wrong bytes and must miss.
    let mut bent = stream.clone();
    let real = u32::from_le_bytes(bent[header..header + 4].try_into().unwrap());
    bent[header..header + 4].copy_from_slice(&(real + 4).to_le_bytes());
    let scan = flight::scan_bytes(&bent);
    assert!(scan.entries.is_empty());
    assert!(scan.truncated.is_some());
}

#[test]
fn flight_torn_tail_is_reported_not_fatal() {
    // Truncate at every byte boundary inside the last frame: the scan
    // keeps everything before it and names the tear.
    let stream = sample_flight_stream();
    let clean = flight::scan_bytes(&stream);
    let last_start = {
        // Walk frames to find where the final one begins.
        let mut off = 8usize;
        let mut start = off;
        while off < stream.len() {
            let len =
                u32::from_le_bytes(stream[off..off + 4].try_into().unwrap()) as usize;
            start = off;
            off += 4 + len + 4;
        }
        start
    };
    for cut in last_start + 1..stream.len() {
        let scan = flight::scan_bytes(&stream[..cut]);
        assert_eq!(scan.entries.len(), clean.entries.len() - 1, "cut {cut}");
        assert!(scan.truncated.is_some(), "cut {cut}: tear not reported");
    }
}
